//! API layer (§3.2): Create / Describe / List / Stop HyperParameterTuningJob.
//!
//! The AWS deployment fronts these with API Gateway + Lambda; here they are
//! methods on [`AmtService`], the in-process service facade. Semantics
//! match the paper's design requirements:
//!
//! * synchronous APIs validate and persist to the metadata store before
//!   returning (the §3.1 availability pillar — the §6.5 soak bench measures
//!   their success rate under load);
//! * the asynchronous tuning workflow runs as a [`crate::coordinator::JobActor`]
//!   on the multi-tenant [`crate::scheduler::Scheduler`] — a fixed worker
//!   pool multiplexes every tuning job, each on its own platform timeline;
//! * `wait` blocks on the job's own condvar, never on a service-wide lock,
//!   so one slow job cannot stall Create/Describe/Stop for other tenants;
//! * `StopHyperParameterTuningJob` flips a per-job flag the workflow
//!   observes at its next scheduling point;
//! * warm start resolves parent jobs *through the store* with paginated
//!   scans, so chained jobs behave exactly like the §6.4 case study.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::config::TuningJobRequest;
use crate::coordinator::{stopping_by_name, JobActor, TuningJobOutcome};
use crate::durability::{recovery, snapshot, wal::Wal};
use crate::gp::{NativeBackend, SurrogateBackend};
use crate::json::Json;
use crate::metrics::MetricsService;
use crate::objectives::by_name as objective_by_name;
use crate::platform::{PlatformConfig, TrainingPlatform};
use crate::scheduler::{Scheduler, SchedulerConfig};
use crate::space::{config_from_json, Config, Value};
use crate::store::MetadataStore;
use crate::strategies::{BayesianOptimization, BoConfig, Observation, Strategy};
use crate::warmstart::{transfer, ParentJob, TransferOptions};

/// Page size for store scans performed inside API handlers (warm-start
/// parent resolution): bounds how long any one shard lock is held.
const SCAN_PAGE: usize = 128;

/// API error codes (the synchronous 4xx/5xx surface).
#[derive(Debug, PartialEq, Eq)]
pub enum ApiError {
    /// Request failed validation.
    Validation(String),
    /// A tuning job with this name already exists.
    AlreadyExists(String),
    /// No such tuning job.
    NotFound(String),
    /// A named warm-start parent does not exist or has no results.
    BadParent(String),
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}

impl std::error::Error for ApiError {}

/// Tuning-job summary returned by List/Describe.
#[derive(Clone, Debug)]
pub struct TuningJobSummary {
    /// Job name.
    pub name: String,
    /// "InProgress" | "Completed" | "Stopped" | "Failed".
    pub status: String,
    /// Finished evaluations so far.
    pub evaluations: usize,
    /// Best raw metric value so far, if any.
    pub best_value: Option<f64>,
}

/// The fully managed tuning service (in-process facade).
pub struct AmtService {
    store: Arc<MetadataStore>,
    metrics: Arc<MetricsService>,
    platform_config: PlatformConfig,
    backend: Arc<dyn SurrogateBackend>,
    scheduler: Scheduler,
    /// Durability log (None for the in-memory-only constructors).
    wal: Option<Arc<Wal>>,
    /// Durability directory `open` was pointed at.
    data_dir: Option<PathBuf>,
    /// Names of the non-terminal jobs `open` resumed, name-sorted.
    recovered: Vec<String>,
    /// API call counters for the §6.5 availability accounting.
    pub api_calls: std::sync::atomic::AtomicU64,
    /// API calls that returned an error.
    pub api_errors: std::sync::atomic::AtomicU64,
}

/// The durable service handle (`TuningService::open` / `close` in the
/// durability-engine design) — the same facade, named for the role.
pub type TuningService = AmtService;

impl AmtService {
    /// New service with the native surrogate backend.
    pub fn new(platform_config: PlatformConfig) -> Self {
        Self::with_backend(platform_config, Arc::new(NativeBackend))
    }

    /// New service with an explicit surrogate backend (e.g. the PJRT/HLO
    /// backend from [`crate::runtime`]).
    pub fn with_backend(
        platform_config: PlatformConfig,
        backend: Arc<dyn SurrogateBackend>,
    ) -> Self {
        Self::with_options(platform_config, backend, SchedulerConfig::default())
    }

    /// New service with explicit backend and scheduler configuration.
    pub fn with_options(
        platform_config: PlatformConfig,
        backend: Arc<dyn SurrogateBackend>,
        scheduler_config: SchedulerConfig,
    ) -> Self {
        AmtService {
            store: Arc::new(MetadataStore::new()),
            metrics: Arc::new(MetricsService::new()),
            platform_config,
            backend,
            scheduler: Scheduler::new(scheduler_config),
            wal: None,
            data_dir: None,
            recovered: Vec::new(),
            api_calls: std::sync::atomic::AtomicU64::new(0),
            api_errors: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Open a **durable** service rooted at `dir` with the native
    /// backend: load per-shard snapshots, replay the WAL tail, and resume
    /// every non-terminal tuning job (see
    /// [`AmtService::open_with_options`]).
    pub fn open(dir: impl AsRef<Path>, platform_config: PlatformConfig) -> crate::Result<Self> {
        Self::open_with_options(
            dir,
            platform_config,
            Arc::new(NativeBackend),
            SchedulerConfig::default(),
        )
    }

    /// Open a durable service: recovery-on-open.
    ///
    /// Rebuilds the store and metrics from `dir` (snapshots + WAL tail —
    /// an empty or absent directory yields a fresh durable service),
    /// attaches the reopened WAL to every write path, then re-`activate`s
    /// each tuning job whose persisted status is still `InProgress`:
    /// its partial records are reset and the job replays
    /// deterministically from its request seed, finishing with exactly
    /// the trajectory — and final store contents — of an uninterrupted
    /// run (`rust/tests/durability_integration.rs` pins this at random
    /// WAL cut points). For bit-identity the service must be reopened
    /// with the same `platform_config` the jobs originally ran under.
    ///
    /// Jobs whose objective is not in the registry (custom-algorithm
    /// jobs) cannot be re-instantiated from metadata alone and are marked
    /// `Failed` in the store instead of resumed.
    pub fn open_with_options(
        dir: impl AsRef<Path>,
        platform_config: PlatformConfig,
        backend: Arc<dyn SurrogateBackend>,
        scheduler_config: SchedulerConfig,
    ) -> crate::Result<Self> {
        let recovered = recovery::open(dir.as_ref())?;
        let scheduler = Scheduler::new(scheduler_config);
        scheduler.set_wal(Arc::clone(&recovered.wal));
        let mut svc = AmtService {
            store: recovered.store,
            metrics: recovered.metrics,
            platform_config,
            backend,
            scheduler,
            wal: Some(Arc::clone(&recovered.wal)),
            data_dir: Some(dir.as_ref().to_path_buf()),
            recovered: Vec::new(),
            api_calls: std::sync::atomic::AtomicU64::new(0),
            api_errors: std::sync::atomic::AtomicU64::new(0),
        };
        for job in &recovered.jobs {
            if job.status != "InProgress" {
                continue;
            }
            let persisted_request = job.request.clone().unwrap_or(Json::Null);
            let request = job
                .request
                .as_ref()
                .and_then(TuningJobRequest::from_json);
            let Some(request) = request else {
                svc.mark_unrecoverable(
                    &job.name,
                    "persisted request unparseable",
                    persisted_request,
                );
                continue;
            };
            let Some(objective) = objective_by_name(&request.objective) else {
                svc.mark_unrecoverable(
                    &job.name,
                    "custom/unknown objective cannot be re-instantiated",
                    persisted_request,
                );
                continue;
            };
            if let Err(e) = request.validate_with_custom_objective() {
                svc.mark_unrecoverable(
                    &job.name,
                    &format!("persisted request invalid: {e}"),
                    persisted_request,
                );
                continue;
            }
            // the transfer observations persisted at the original create
            // (if any) — read before the reset deletes them
            let persisted_transfer = svc
                .store
                .get("warm_start", &request.name)
                .and_then(|(_, j)| observations_from_json(&j));
            // reset the partial records, then drive the job through the
            // ordinary create path: deterministic replay re-produces every
            // put (same order ⇒ same values and versions) and runs on to
            // completion
            svc.reset_job_state(&request.name);
            let name = request.name.clone();
            let result = match persisted_transfer {
                Some(obs) => svc.create_prepared(request, objective.into(), obs),
                None => svc.create_with_objective(request, objective.into()),
            };
            match result {
                Ok(_) => svc.recovered.push(name),
                Err(e) => svc.mark_unrecoverable(
                    &name,
                    &format!("resume failed: {e}"),
                    persisted_request,
                ),
            }
        }
        Ok(svc)
    }

    /// Delete every store record and metric stream a job wrote, so its
    /// deterministic replay starts from a clean slate (versions restart
    /// at 1, exactly like an uninterrupted run). The deletions go through
    /// the logged paths, keeping the WAL a faithful mutation history.
    /// The `{name}-train-` prefixes cannot reach a sibling job's records:
    /// job names may not contain `-train-` (request validation), so no
    /// other job name is an extension of this prefix.
    fn reset_job_state(&self, name: &str) {
        self.store.delete("tuning_jobs", name);
        self.store.delete("warm_start", name);
        for key in self.store.list_keys("training_jobs", &format!("{name}-train-")) {
            self.store.delete("training_jobs", &key);
        }
        self.metrics.remove_streams(&format!("{name}-train-"));
        self.metrics.remove_streams(&format!("{name}/"));
    }

    /// Persist a `Failed` terminal record for a job recovery could not
    /// resume, carrying the original request wire JSON (the caller holds
    /// it — the store record may already have been reset).
    fn mark_unrecoverable(&self, name: &str, reason: &str, request: Json) {
        self.store.put(
            "tuning_jobs",
            name,
            Json::obj(vec![
                ("status", Json::Str("Failed".into())),
                ("request", request),
                ("failure_reason", Json::Str(reason.into())),
            ]),
        );
    }

    /// Names of the non-terminal jobs recovery resumed, name-sorted.
    pub fn recovered_jobs(&self) -> &[String] {
        &self.recovered
    }

    /// The durability WAL, when this service was `open`ed durably.
    pub fn wal(&self) -> Option<Arc<Wal>> {
        self.wal.clone()
    }

    /// Write a per-shard point-in-time snapshot of the current state to
    /// the durability directory (bounding future WAL replay). No-op for
    /// in-memory services.
    pub fn checkpoint(&self) -> crate::Result<()> {
        if let (Some(wal), Some(dir)) = (&self.wal, &self.data_dir) {
            wal.commit()?;
            snapshot::write_snapshot(dir, &self.store, &self.metrics, wal)?;
        }
        Ok(())
    }

    /// Close a durable service: final WAL commit + per-shard snapshot.
    /// Jobs still in flight stay `InProgress` in the snapshot and are
    /// resumed by the next [`AmtService::open`].
    pub fn close(self) -> crate::Result<()> {
        self.checkpoint()
    }

    /// Worker threads in the scheduler pool — the service's fixed OS-thread
    /// budget for tuning workflows, independent of how many jobs run.
    pub fn worker_count(&self) -> usize {
        self.scheduler.worker_count()
    }

    /// Tuning jobs submitted and not yet finished.
    pub fn running_jobs(&self) -> usize {
        self.scheduler.running_jobs()
    }

    /// Shared metadata store (read-only use recommended).
    pub fn store(&self) -> Arc<MetadataStore> {
        Arc::clone(&self.store)
    }

    /// Shared metrics service.
    pub fn metrics(&self) -> Arc<MetricsService> {
        Arc::clone(&self.metrics)
    }

    fn count_call(&self) {
        self.api_calls.fetch_add(1, Ordering::Relaxed);
    }

    fn fail<T>(&self, e: ApiError) -> Result<T, ApiError> {
        self.api_errors.fetch_add(1, Ordering::Relaxed);
        Err(e)
    }

    /// Resolve warm-start parents from the store into transfer observations.
    fn resolve_parents_for(
        &self,
        request: &TuningJobRequest,
        sign: f64,
        child_space: &crate::space::SearchSpace,
    ) -> Result<Vec<Observation>, ApiError> {
        if request.warm_start_parents.is_empty() {
            return Ok(Vec::new());
        }
        let mut parents = Vec::new();
        for pname in &request.warm_start_parents {
            // parent tuning job must exist and be terminal
            let Some((_, job)) = self.store.get("tuning_jobs", pname) else {
                return self.fail(ApiError::BadParent(pname.clone()));
            };
            let pobj_name = job
                .get("request")
                .and_then(|r| r.get("objective"))
                .and_then(Json::as_str)
                .unwrap_or(&request.objective)
                .to_string();
            let pspace = objective_by_name(&pobj_name)
                .map(|o| o.space())
                .unwrap_or_else(|| child_space.clone());
            // paginated scan: bounded pages instead of one whole-prefix
            // clone under the store's shard locks
            let mut observations = Vec::new();
            let prefix = format!("{pname}-train-");
            let mut cursor: Option<String> = None;
            loop {
                let page =
                    self.store.scan_page("training_jobs", &prefix, cursor.as_deref(), SCAN_PAGE);
                let Some((last_key, _)) = page.last() else { break };
                // a partial page means the prefix is exhausted — no need
                // for a follow-up call that would come back empty
                let exhausted = page.len() < SCAN_PAGE;
                cursor = Some(last_key.clone());
                for (_, rec) in page {
                    let Some(vj) = rec.get("final_value") else { continue };
                    let Some(v) = vj.as_f64() else { continue };
                    let Some(cfg) = rec.get("config").and_then(config_from_json) else {
                        continue;
                    };
                    // coerce numeric strings back into the parent space types
                    let cfg = pspace.clamp(&cfg);
                    observations.push(Observation { config: cfg, value: sign * v });
                }
                if exhausted {
                    break;
                }
            }
            if observations.is_empty() {
                return self.fail(ApiError::BadParent(pname.clone()));
            }
            parents.push(ParentJob { name: pname.clone(), space: pspace, observations });
        }
        Ok(transfer(&parents, child_space, &TransferOptions::default()))
    }

    /// `CreateHyperParameterTuningJob`: validate, persist, start the
    /// asynchronous workflow. Returns the job name (stand-in for the ARN).
    pub fn create_tuning_job(&self, request: TuningJobRequest) -> Result<String, ApiError> {
        self.count_call();
        if let Err(e) = request.validate() {
            return self.fail(ApiError::Validation(e.to_string()));
        }
        let objective: Arc<dyn crate::objectives::Objective> =
            objective_by_name(&request.objective).expect("validated").into();
        self.create_with_objective(request, objective)
    }

    /// Tune a *custom algorithm* (the paper: "AMT can be used with built-in
    /// algorithms, custom algorithms, and ... pre-built containers"): same
    /// workflow, caller-supplied objective. The request's `objective` field
    /// is treated as a label; validation of the other fields still applies.
    pub fn create_custom_tuning_job(
        &self,
        request: TuningJobRequest,
        objective: Arc<dyn crate::objectives::Objective>,
    ) -> Result<String, ApiError> {
        self.count_call();
        if let Err(e) = request.validate_with_custom_objective() {
            return self.fail(ApiError::Validation(e.to_string()));
        }
        self.create_with_objective(request, objective)
    }

    fn create_with_objective(
        &self,
        request: TuningJobRequest,
        objective: Arc<dyn crate::objectives::Objective>,
    ) -> Result<String, ApiError> {
        if self.scheduler.contains(&request.name)
            || self.store.get("tuning_jobs", &request.name).is_some()
        {
            return self.fail(ApiError::AlreadyExists(request.name));
        }

        let sign = if objective.minimize() { 1.0 } else { -1.0 };
        let transferred = self.resolve_parents_for(&request, sign, &objective.space())?;
        self.create_prepared(request, objective, transferred)
    }

    /// Final leg of job creation, with the warm-start transfer
    /// observations already resolved. They are persisted to the
    /// `warm_start` table *before* the job record, so recovery re-enters
    /// here with exactly the observations the original create computed —
    /// a resumed warm-start child never re-resolves against parents that
    /// may themselves still be mid-replay.
    fn create_prepared(
        &self,
        request: TuningJobRequest,
        objective: Arc<dyn crate::objectives::Objective>,
        transferred: Vec<Observation>,
    ) -> Result<String, ApiError> {
        let transfer_json = if transferred.is_empty() {
            None
        } else {
            Some(observations_to_json(&transferred))
        };

        // build the strategy (BO gets the warm-start observations)
        let strategy: Box<dyn Strategy> = match request.strategy.as_str() {
            "bayesian" | "bo" => {
                let mut bo = BayesianOptimization::new(
                    objective.space(),
                    Arc::clone(&self.backend),
                    BoConfig::default(),
                    request.seed,
                );
                bo.add_transferred(transferred);
                Box::new(bo)
            }
            other => crate::strategies::by_name(
                other,
                &objective.space(),
                Arc::clone(&self.backend),
                request.seed,
            )
            .expect("validated strategy"),
        };
        let stopping = stopping_by_name(&request.early_stopping).expect("validated");

        let stop_flag = Arc::new(AtomicBool::new(false));
        let actor = JobActor::new(
            request.clone(),
            objective,
            strategy,
            stopping,
            TrainingPlatform::new(self.platform_config.clone(), request.seed),
            Arc::clone(&self.store),
            Arc::clone(&self.metrics),
            Arc::clone(&stop_flag),
        );
        // reserve the name first (atomic duplicate check), then persist the
        // accepted request, then let workers at it — a losing concurrent
        // create never touches the store, and the record is always in the
        // store before the workflow can run
        if !self.scheduler.register(actor, stop_flag) {
            return self.fail(ApiError::AlreadyExists(request.name));
        }
        // warm-start observations first, job record second: any WAL
        // prefix containing the job record also contains the transfer
        // data its recovery needs
        if let Some(tj) = transfer_json {
            self.store.put(
                "warm_start",
                &request.name,
                Json::obj(vec![("observations", tj)]),
            );
        }
        self.store.put(
            "tuning_jobs",
            &request.name,
            Json::obj(vec![
                ("status", Json::Str("InProgress".into())),
                ("request", request.to_json()),
            ]),
        );
        self.scheduler.activate(&request.name);
        Ok(request.name)
    }

    /// Block until a tuning job's workflow finishes; returns its outcome.
    ///
    /// Blocks on the job's own condvar (never a service-wide lock), so
    /// concurrent Create/Describe/Stop/wait calls for other jobs proceed
    /// unimpeded while this one waits.
    pub fn wait(&self, name: &str) -> Result<TuningJobOutcome, ApiError> {
        match self.scheduler.wait(name) {
            Some(outcome) => Ok(outcome),
            None => self.fail(ApiError::NotFound(name.to_string())),
        }
    }

    /// `DescribeHyperParameterTuningJob`.
    pub fn describe_tuning_job(&self, name: &str) -> Result<TuningJobSummary, ApiError> {
        self.count_call();
        let Some((_, job)) = self.store.get("tuning_jobs", name) else {
            return self.fail(ApiError::NotFound(name.to_string()));
        };
        let status = job
            .get("status")
            .and_then(Json::as_str)
            .unwrap_or("Unknown")
            .to_string();
        let mut evaluations = 0;
        let mut best: Option<f64> = None;
        let minimize = job
            .get("request")
            .and_then(|r| r.get("objective"))
            .and_then(Json::as_str)
            .and_then(objective_by_name)
            .map(|o| o.minimize())
            .unwrap_or(true);
        for (_, rec) in self.store.scan("training_jobs", &format!("{name}-train-")) {
            let terminal = matches!(
                rec.get("status").and_then(Json::as_str),
                Some("Completed") | Some("Stopped") | Some("Failed")
            );
            if terminal {
                evaluations += 1;
            }
            if let Some(v) = rec.get("final_value").and_then(Json::as_f64) {
                best = Some(match best {
                    None => v,
                    Some(b) => {
                        if minimize {
                            b.min(v)
                        } else {
                            b.max(v)
                        }
                    }
                });
            }
        }
        Ok(TuningJobSummary { name: name.to_string(), status, evaluations, best_value: best })
    }

    /// `ListHyperParameterTuningJobs` (optionally by name prefix).
    pub fn list_tuning_jobs(&self, prefix: &str) -> Vec<String> {
        self.count_call();
        self.store.list_keys("tuning_jobs", prefix)
    }

    /// `StopHyperParameterTuningJob`: signal the workflow to stop. The
    /// call is asynchronous, like the AWS API, and never blocks on other
    /// jobs — it only flips the target job's stop flag.
    pub fn stop_tuning_job(&self, name: &str) -> Result<(), ApiError> {
        self.count_call();
        if self.scheduler.stop(name) {
            Ok(())
        } else {
            self.fail(ApiError::NotFound(name.to_string()))
        }
    }

    /// Availability ratio over the service lifetime (§6.5: "API
    /// communication was available ... for the 99.99% of time").
    pub fn availability(&self) -> f64 {
        let calls = self.api_calls.load(Ordering::Relaxed);
        let errors = self.api_errors.load(Ordering::Relaxed);
        if calls == 0 {
            1.0
        } else {
            1.0 - errors as f64 / calls as f64
        }
    }
}

/// Convenience for tests/benches: extract a numeric HP from a config.
pub fn config_num(config: &crate::space::Config, key: &str) -> Option<f64> {
    config.get(key).and_then(Value::as_f64)
}

/// Wire form of warm-start transfer observations (the `warm_start`
/// table's `observations` field). Unlike the untyped
/// [`crate::space::config_to_json`] (whose reader collapses ints to
/// floats), values are tagged by variant — `Int` as `{"int": n}` — so
/// the round trip is exact and a recovered child's strategy seeds with
/// *exactly* the observations the original create resolved (f64s
/// round-trip bit-exactly through the JSON layer).
fn observations_to_json(obs: &[Observation]) -> Json {
    let value_json = |v: &Value| match v {
        Value::Float(f) => Json::Num(*f),
        Value::Int(i) => Json::obj(vec![("int", Json::Num(*i as f64))]),
        Value::Cat(s) => Json::Str(s.clone()),
    };
    Json::Arr(
        obs.iter()
            .map(|o| {
                Json::obj(vec![
                    (
                        "config",
                        Json::Obj(
                            o.config
                                .iter()
                                .map(|(k, v)| (k.clone(), value_json(v)))
                                .collect(),
                        ),
                    ),
                    ("value", Json::Num(o.value)),
                ])
            })
            .collect(),
    )
}

fn observations_from_json(record: &Json) -> Option<Vec<Observation>> {
    let value_back = |j: &Json| -> Option<Value> {
        match j {
            Json::Num(n) => Some(Value::Float(*n)),
            Json::Str(s) => Some(Value::Cat(s.clone())),
            Json::Obj(_) => Some(Value::Int(j.get("int")?.as_i64()?)),
            _ => None,
        }
    };
    let arr = record.get("observations")?.as_arr()?;
    let mut out = Vec::with_capacity(arr.len());
    for entry in arr {
        let cobj = entry.get("config")?.as_obj()?;
        let mut config = Config::new();
        for (k, vj) in cobj {
            config.insert(k.clone(), value_back(vj)?);
        }
        out.push(Observation { config, value: entry.get("value")?.as_f64()? });
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_request(name: &str, jobs: u32) -> TuningJobRequest {
        TuningJobRequest {
            name: name.into(),
            objective: "branin".into(),
            strategy: "random".into(),
            max_training_jobs: jobs,
            max_parallel_jobs: 2,
            ..Default::default()
        }
    }

    #[test]
    fn create_wait_describe_lifecycle() {
        let svc = AmtService::new(PlatformConfig::noiseless());
        let name = svc.create_tuning_job(quick_request("job-a", 5)).unwrap();
        let outcome = svc.wait(&name).unwrap();
        assert_eq!(outcome.evaluations.len(), 5);
        let d = svc.describe_tuning_job(&name).unwrap();
        assert_eq!(d.status, "Completed");
        assert_eq!(d.evaluations, 5);
        assert!(d.best_value.is_some());
        assert_eq!(svc.list_tuning_jobs("job-"), vec!["job-a"]);
    }

    #[test]
    fn duplicate_names_rejected() {
        let svc = AmtService::new(PlatformConfig::noiseless());
        svc.create_tuning_job(quick_request("dup", 2)).unwrap();
        assert!(matches!(
            svc.create_tuning_job(quick_request("dup", 2)),
            Err(ApiError::AlreadyExists(_))
        ));
        svc.wait("dup").unwrap();
    }

    #[test]
    fn validation_errors_surface() {
        let svc = AmtService::new(PlatformConfig::noiseless());
        let mut r = quick_request("bad", 2);
        r.objective = "nonexistent".into();
        assert!(matches!(svc.create_tuning_job(r), Err(ApiError::Validation(_))));
        assert!(svc.availability() < 1.0);
    }

    #[test]
    fn describe_and_stop_missing_jobs() {
        let svc = AmtService::new(PlatformConfig::noiseless());
        assert!(matches!(svc.describe_tuning_job("ghost"), Err(ApiError::NotFound(_))));
        assert!(matches!(svc.stop_tuning_job("ghost"), Err(ApiError::NotFound(_))));
    }

    #[test]
    fn stop_terminates_early() {
        let svc = AmtService::new(PlatformConfig::noiseless());
        let name = svc
            .create_tuning_job(quick_request("stoppable", 500))
            .unwrap();
        svc.stop_tuning_job(&name).unwrap();
        let outcome = svc.wait(&name).unwrap();
        assert!(outcome.evaluations.len() < 500);
        let d = svc.describe_tuning_job(&name).unwrap();
        assert_eq!(d.status, "Stopped");
    }

    #[test]
    fn warm_start_resolves_parent_from_store() {
        let svc = AmtService::new(PlatformConfig::noiseless());
        svc.create_tuning_job(quick_request("parent", 6)).unwrap();
        svc.wait("parent").unwrap();

        let mut child = quick_request("child", 4);
        child.strategy = "bayesian".into();
        child.warm_start_parents = vec!["parent".into()];
        let name = svc.create_tuning_job(child).unwrap();
        let outcome = svc.wait(&name).unwrap();
        assert_eq!(outcome.evaluations.len(), 4);
    }

    #[test]
    fn warm_start_rejects_unknown_parent() {
        let svc = AmtService::new(PlatformConfig::noiseless());
        let mut r = quick_request("orphan", 2);
        r.strategy = "bayesian".into();
        r.warm_start_parents = vec!["never-existed".into()];
        assert!(matches!(svc.create_tuning_job(r), Err(ApiError::BadParent(_))));
    }

    #[test]
    fn concurrent_tuning_jobs_run() {
        let svc = Arc::new(AmtService::new(PlatformConfig::noiseless()));
        for i in 0..4 {
            svc.create_tuning_job(quick_request(&format!("par-{i}"), 3)).unwrap();
        }
        for i in 0..4 {
            let out = svc.wait(&format!("par-{i}")).unwrap();
            assert_eq!(out.evaluations.len(), 3);
        }
        assert_eq!(svc.list_tuning_jobs("par-").len(), 4);
        assert_eq!(svc.availability(), 1.0);
    }
}
