//! API layer (§3.2): Create / Describe / List / Stop HyperParameterTuningJob.
//!
//! The AWS deployment fronts these with API Gateway + Lambda; here they are
//! methods on [`AmtService`], the in-process service facade. Semantics
//! match the paper's design requirements:
//!
//! * synchronous APIs validate and persist to the metadata store before
//!   returning (the §3.1 availability pillar — the §6.5 soak bench measures
//!   their success rate under load);
//! * the asynchronous tuning workflow runs as a [`crate::coordinator::JobActor`]
//!   on the multi-tenant [`crate::scheduler::Scheduler`] — a fixed worker
//!   pool multiplexes every tuning job, each on its own platform timeline;
//! * `wait` blocks on the job's own condvar, never on a service-wide lock,
//!   so one slow job cannot stall Create/Describe/Stop for other tenants;
//! * `StopHyperParameterTuningJob` flips a per-job flag the workflow
//!   observes at its next scheduling point;
//! * warm start resolves parent jobs *through the store* with paginated
//!   scans, so chained jobs behave exactly like the §6.4 case study.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::config::TuningJobRequest;
use crate::coordinator::{actor_from_snapshot, stopping_by_name, JobActor, TuningJobOutcome};
use crate::distributed::leader::{RemoteConfig, RemoteJobSpec, RemoteWorkerPool};
use crate::distributed::transport::Transport;
use crate::durability::{recovery, snapshot, wal::Wal, DurabilityOptions};
use crate::gp::{NativeBackend, SurrogateBackend};
use crate::json::Json;
use crate::metrics::MetricsService;
use crate::objectives::by_name as objective_by_name;
use crate::platform::{PlatformConfig, TrainingPlatform};
use crate::scheduler::{Scheduler, SchedulerConfig};
use crate::space::{config_from_json, Value};
use crate::store::MetadataStore;
use crate::strategies::{observations_from_json, observations_to_json, Observation, Strategy};
use crate::telemetry::{self, MetricSnapshot, MetricValue, TelemetrySnapshot};
use crate::warmstart::{transfer, ParentJob, TransferOptions};

/// Page size for store scans performed inside API handlers (warm-start
/// parent resolution): bounds how long any one shard lock is held.
const SCAN_PAGE: usize = 128;

/// API error codes (the synchronous 4xx/5xx surface).
#[derive(Debug, PartialEq, Eq)]
pub enum ApiError {
    /// Request failed validation.
    Validation(String),
    /// A tuning job with this name already exists.
    AlreadyExists(String),
    /// No such tuning job.
    NotFound(String),
    /// A named warm-start parent does not exist or has no results.
    BadParent(String),
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}

impl std::error::Error for ApiError {}

/// How recovery-on-open resumed the non-terminal jobs it found
/// (DESIGN.md §12). The split is the observable the incremental-resume
/// property tests and `benches/recovery.rs` assert on.
#[derive(Clone, Copy, Debug, Default)]
pub struct RecoveryStats {
    /// Jobs rebuilt directly from a v1 resume snapshot — O(remaining
    /// work), zero strategy proposals re-executed.
    pub fast_resumed: usize,
    /// Jobs resumed by scratch replay (reset + deterministic re-create
    /// from the request seed) — the pre-v1 path, still exact.
    pub scratch_resumed: usize,
    /// Strategy proposals re-executed during recovery: for each
    /// scratch-replayed job, the evaluations that already existed before
    /// the crash (snapshot-resumed jobs contribute 0 by construction).
    pub replayed_proposals: u64,
}

/// Tuning-job summary returned by List/Describe.
#[derive(Clone, Debug)]
pub struct TuningJobSummary {
    /// Job name.
    pub name: String,
    /// "InProgress" | "Completed" | "Stopped" | "Failed".
    pub status: String,
    /// Finished evaluations so far.
    pub evaluations: usize,
    /// Best raw metric value so far, if any.
    pub best_value: Option<f64>,
}

/// The fully managed tuning service (in-process facade).
pub struct AmtService {
    store: Arc<MetadataStore>,
    metrics: Arc<MetricsService>,
    platform_config: PlatformConfig,
    backend: Arc<dyn SurrogateBackend>,
    scheduler: Scheduler,
    /// Remote execution plane: jobs whose objective lives in the
    /// registry dispatch here when attached; custom-objective jobs (and
    /// everything else when absent) run on the local scheduler.
    remote: Option<Arc<RemoteWorkerPool>>,
    /// Durability log (None for the in-memory-only constructors).
    wal: Option<Arc<Wal>>,
    /// Auto-checkpoint trigger installed on every execution plane's
    /// group-commit path (None when `auto_checkpoint_bytes` is unset).
    post_commit_hook: Option<Arc<dyn Fn() + Send + Sync>>,
    /// Durability directory `open` was pointed at.
    data_dir: Option<PathBuf>,
    /// Names of the non-terminal jobs `open` resumed, name-sorted.
    recovered: Vec<String>,
    /// How those jobs were resumed (snapshot fast path vs scratch).
    recovery_stats: RecoveryStats,
    /// API call counters for the §6.5 availability accounting.
    pub api_calls: std::sync::atomic::AtomicU64,
    /// API calls that returned an error.
    pub api_errors: std::sync::atomic::AtomicU64,
}

/// The durable service handle (`TuningService::open` / `close` in the
/// durability-engine design) — the same facade, named for the role.
pub type TuningService = AmtService;

impl AmtService {
    /// New service with the native surrogate backend.
    pub fn new(platform_config: PlatformConfig) -> Self {
        Self::with_backend(platform_config, Arc::new(NativeBackend))
    }

    /// New service with an explicit surrogate backend (e.g. the PJRT/HLO
    /// backend from [`crate::runtime`]).
    pub fn with_backend(
        platform_config: PlatformConfig,
        backend: Arc<dyn SurrogateBackend>,
    ) -> Self {
        Self::with_options(platform_config, backend, SchedulerConfig::default())
    }

    /// New service with explicit backend and scheduler configuration.
    pub fn with_options(
        platform_config: PlatformConfig,
        backend: Arc<dyn SurrogateBackend>,
        scheduler_config: SchedulerConfig,
    ) -> Self {
        AmtService {
            store: Arc::new(MetadataStore::new()),
            metrics: Arc::new(MetricsService::new()),
            platform_config,
            backend,
            scheduler: Scheduler::new(scheduler_config),
            remote: None,
            wal: None,
            post_commit_hook: None,
            data_dir: None,
            recovered: Vec::new(),
            recovery_stats: RecoveryStats::default(),
            api_calls: std::sync::atomic::AtomicU64::new(0),
            api_errors: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// In-memory service whose registry-objective jobs execute on a
    /// remote worker pool over the given transports (loopback or
    /// socket), native backend, default scheduler/remote configuration.
    pub fn with_remote_workers(
        platform_config: PlatformConfig,
        transports: Vec<Box<dyn Transport>>,
    ) -> Self {
        let mut svc = Self::new(platform_config);
        svc.attach_remote_workers(transports, RemoteConfig::default());
        svc
    }

    /// Attach a remote execution plane: from now on, every created job
    /// whose objective is in the registry dispatches to these workers
    /// (the distributed plane, DESIGN.md §11); custom-objective jobs
    /// stay on the local scheduler, since a remote worker cannot rebuild
    /// an arbitrary objective from its name. Deltas apply into this
    /// service's store/metrics — and its WAL, when the service was
    /// opened durably. Call before creating jobs; jobs resumed by a
    /// durable `open` ran on the local plane already and are untouched.
    pub fn attach_remote_workers(
        &mut self,
        transports: Vec<Box<dyn Transport>>,
        config: RemoteConfig,
    ) {
        let pool = RemoteWorkerPool::new(
            transports,
            Arc::clone(&self.store),
            Arc::clone(&self.metrics),
            self.wal.clone(),
            config,
        );
        // the auto-checkpoint trigger bounds the WAL no matter which
        // plane does the committing
        if let Some(hook) = &self.post_commit_hook {
            pool.set_post_commit(Arc::clone(hook));
        }
        self.remote = Some(Arc::new(pool));
    }

    /// The attached remote worker pool, if any.
    pub fn remote_pool(&self) -> Option<Arc<RemoteWorkerPool>> {
        self.remote.clone()
    }

    /// Admit one more worker into the attached remote plane mid-run
    /// (elastic membership, DESIGN.md §13): the transport gets its own
    /// lane and driver thread, and queued work is rebalanced onto it as
    /// soon as its `Hello` pins a backend. Returns the new lane index,
    /// or `None` when no remote plane is attached.
    pub fn add_remote_worker(&self, transport: Box<dyn Transport>) -> Option<usize> {
        self.remote.as_ref().map(|r| r.add_worker(transport))
    }

    /// Gracefully drain a remote worker lane: its queued jobs migrate to
    /// surviving lanes and its running jobs are checkpointed at the next
    /// poll boundary and resumed elsewhere — zero re-executed proposals.
    /// Returns false when no remote plane is attached or the lane is
    /// already gone.
    pub fn drain_remote_worker(&self, idx: usize) -> bool {
        self.remote.as_ref().is_some_and(|r| r.drain_worker(idx))
    }

    /// Open a **durable** service rooted at `dir` with the native
    /// backend: load per-shard snapshots, replay the WAL tail, and resume
    /// every non-terminal tuning job (see
    /// [`AmtService::open_with_options`]).
    pub fn open(dir: impl AsRef<Path>, platform_config: PlatformConfig) -> crate::Result<Self> {
        Self::open_with_options(
            dir,
            platform_config,
            Arc::new(NativeBackend),
            SchedulerConfig::default(),
        )
    }

    /// Open a durable service: recovery-on-open.
    ///
    /// Rebuilds the store and metrics from `dir` (snapshots + WAL tail —
    /// an empty or absent directory yields a fresh durable service),
    /// attaches the reopened WAL to every write path, then re-`activate`s
    /// each tuning job whose persisted status is still `InProgress`:
    /// its partial records are reset and the job replays
    /// deterministically from its request seed, finishing with exactly
    /// the trajectory — and final store contents — of an uninterrupted
    /// run (`rust/tests/durability_integration.rs` pins this at random
    /// WAL cut points). For bit-identity the service must be reopened
    /// with the same `platform_config` the jobs originally ran under.
    ///
    /// Jobs whose objective is not in the registry (custom-algorithm
    /// jobs) cannot be re-instantiated from metadata alone and are marked
    /// `Failed` in the store instead of resumed.
    pub fn open_with_options(
        dir: impl AsRef<Path>,
        platform_config: PlatformConfig,
        backend: Arc<dyn SurrogateBackend>,
        scheduler_config: SchedulerConfig,
    ) -> crate::Result<Self> {
        Self::open_with_durability(
            dir,
            platform_config,
            backend,
            scheduler_config,
            DurabilityOptions::default(),
        )
    }

    /// [`AmtService::open_with_options`] plus durability tuning: with
    /// `auto_checkpoint_bytes` set, the service snapshots and compacts
    /// its WAL automatically whenever a group commit leaves the log
    /// larger than the threshold, so the log stays bounded over any
    /// service lifetime without manual `checkpoint()` calls; with
    /// `group_commit_window` set, a commit leader lingers that long
    /// before capturing the buffer so concurrent committers share one
    /// write+fsync.
    pub fn open_with_durability(
        dir: impl AsRef<Path>,
        platform_config: PlatformConfig,
        backend: Arc<dyn SurrogateBackend>,
        scheduler_config: SchedulerConfig,
        durability: DurabilityOptions,
    ) -> crate::Result<Self> {
        let recovered = recovery::open(dir.as_ref())?;
        if let Some(window) = durability.group_commit_window {
            // lets concurrent committers (lane drivers, scheduler
            // workers) pile onto one write+fsync
            recovered.wal.set_commit_window(window);
        }
        let scheduler = Scheduler::new(scheduler_config);
        scheduler.set_wal(Arc::clone(&recovered.wal));
        let mut post_commit_hook: Option<Arc<dyn Fn() + Send + Sync>> = None;
        if let Some(limit) = durability.auto_checkpoint_bytes {
            // one checkpoint at a time; concurrent committers skip
            let busy = Arc::new(AtomicBool::new(false));
            let wal = Arc::clone(&recovered.wal);
            let store = Arc::clone(&recovered.store);
            let metrics = Arc::clone(&recovered.metrics);
            let snap_dir = dir.as_ref().to_path_buf();
            let hook: Arc<dyn Fn() + Send + Sync> = Arc::new(move || {
                if wal.synced_len() <= limit || busy.swap(true, Ordering::Acquire) {
                    return;
                }
                if let Ok(manifest) = snapshot::write_snapshot(&snap_dir, &store, &metrics, &wal)
                {
                    let _ = wal.compact(manifest.store_hwm, manifest.metrics_hwm);
                }
                busy.store(false, Ordering::Release);
            });
            scheduler.set_post_commit(Arc::clone(&hook));
            post_commit_hook = Some(hook);
        }
        let mut svc = AmtService {
            store: recovered.store,
            metrics: recovered.metrics,
            platform_config,
            backend,
            scheduler,
            remote: None,
            wal: Some(Arc::clone(&recovered.wal)),
            post_commit_hook,
            data_dir: Some(dir.as_ref().to_path_buf()),
            recovered: Vec::new(),
            recovery_stats: RecoveryStats::default(),
            api_calls: std::sync::atomic::AtomicU64::new(0),
            api_errors: std::sync::atomic::AtomicU64::new(0),
        };
        for job in &recovered.jobs {
            if job.status != "InProgress" {
                continue;
            }
            let persisted_request = job.request.clone().unwrap_or(Json::Null);
            let request = job
                .request
                .as_ref()
                .and_then(TuningJobRequest::from_json);
            let Some(request) = request else {
                svc.mark_unrecoverable(
                    &job.name,
                    "persisted request unparseable",
                    persisted_request,
                );
                continue;
            };
            let Some(objective) = objective_by_name(&request.objective) else {
                svc.mark_unrecoverable(
                    &job.name,
                    "custom/unknown objective cannot be re-instantiated",
                    persisted_request,
                );
                continue;
            };
            if let Err(e) = request.validate_with_custom_objective() {
                svc.mark_unrecoverable(
                    &job.name,
                    &format!("persisted request invalid: {e}"),
                    persisted_request,
                );
                continue;
            }
            // O(remaining work) fast path (DESIGN.md §12): recovery
            // aligned this job's store/metrics state to exactly its last
            // v1 checkpoint, so the actor rebuilds from the snapshot and
            // resumes mid-flight — no reset, no re-created records, zero
            // strategy proposals re-executed
            if let Some(snap) = &job.resume {
                let stop_flag = Arc::new(AtomicBool::new(false));
                match actor_from_snapshot(
                    request.clone(),
                    snap,
                    Arc::clone(&svc.backend),
                    Arc::clone(&svc.store),
                    Arc::clone(&svc.metrics),
                    Arc::clone(&stop_flag),
                ) {
                    Ok(actor) => {
                        let due = actor.due();
                        if svc.scheduler.register(actor, stop_flag) {
                            svc.scheduler.activate_at(&request.name, due);
                            svc.recovered.push(request.name.clone());
                            svc.recovery_stats.fast_resumed += 1;
                            continue;
                        }
                        // a name collision on a fresh scheduler cannot
                        // happen; fall through to scratch defensively
                    }
                    Err(_) => {
                        // schema/kind mismatch (e.g. a snapshot written
                        // by a different build): scratch replay below is
                        // always exact
                    }
                }
            }
            // scratch replay: the transfer observations persisted at the
            // original create (if any) — read before the reset deletes
            // them
            let persisted_transfer = svc
                .store
                .get("warm_start", &request.name)
                .and_then(|(_, j)| observations_from_json(j.get("observations")?));
            // reset the partial records, then drive the job through the
            // ordinary create path: deterministic replay re-produces every
            // put (same order ⇒ same values and versions) and runs on to
            // completion
            svc.recovery_stats.scratch_resumed += 1;
            svc.recovery_stats.replayed_proposals += svc
                .store
                .list_keys("training_jobs", &format!("{}-train-", request.name))
                .len() as u64;
            // the reset deletes and the reseed puts must land in the WAL
            // as one atomic unit: a commit slipping between them would
            // persist a state with the job deleted but not re-created,
            // which a second crash could expose (guard borrowed from a
            // local clone; dropped before anything that could commit on
            // this thread)
            let wal_unit_owner = svc.wal.clone();
            let reseed_unit = wal_unit_owner.as_ref().map(|w| w.begin_unit());
            svc.reset_job_state(&request.name);
            let name = request.name.clone();
            let result = match persisted_transfer {
                Some(obs) => svc.create_prepared(request, objective.into(), obs, true),
                None => svc.create_with_objective(request, objective.into(), true),
            };
            drop(reseed_unit);
            match result {
                Ok(_) => svc.recovered.push(name),
                Err(e) => svc.mark_unrecoverable(
                    &name,
                    &format!("resume failed: {e}"),
                    persisted_request,
                ),
            }
        }
        Ok(svc)
    }

    /// Reset a job's records for deterministic replay (see
    /// [`reset_job_records`] — the deletions go through the logged
    /// paths, keeping the WAL a faithful mutation history).
    fn reset_job_state(&self, name: &str) {
        reset_job_records(&self.store, &self.metrics, name);
    }

    /// Persist a `Failed` terminal record for a job recovery could not
    /// resume, carrying the original request wire JSON (the caller holds
    /// it — the store record may already have been reset).
    fn mark_unrecoverable(&self, name: &str, reason: &str, request: Json) {
        persist_job_failed(&self.store, name, request, reason);
    }

    /// Names of the non-terminal jobs recovery resumed, name-sorted.
    pub fn recovered_jobs(&self) -> &[String] {
        &self.recovered
    }

    /// How recovery-on-open resumed those jobs: snapshot fast path vs
    /// scratch replay, and the strategy proposals re-executed (0 for
    /// every snapshot-resumed job).
    pub fn recovery_stats(&self) -> RecoveryStats {
        self.recovery_stats
    }

    /// The durability WAL, when this service was `open`ed durably.
    pub fn wal(&self) -> Option<Arc<Wal>> {
        self.wal.clone()
    }

    /// Write a per-shard point-in-time snapshot of the current state to
    /// the durability directory, then compact the WAL: the committed
    /// prefix both high-water marks cover is truncated away, so the log
    /// holds only records the snapshot does not (recovery after
    /// compaction is bit-identical — the dropped records were exactly
    /// the ones replay would have skipped). No-op for in-memory
    /// services.
    pub fn checkpoint(&self) -> crate::Result<()> {
        if let (Some(wal), Some(dir)) = (&self.wal, &self.data_dir) {
            wal.commit()?;
            let manifest = snapshot::write_snapshot(dir, &self.store, &self.metrics, wal)?;
            wal.compact(manifest.store_hwm, manifest.metrics_hwm)?;
        }
        Ok(())
    }

    /// Close a durable service: final WAL commit + per-shard snapshot.
    /// Jobs still in flight stay `InProgress` in the snapshot and are
    /// resumed by the next [`AmtService::open`].
    pub fn close(self) -> crate::Result<()> {
        self.checkpoint()
    }

    /// Worker threads in the scheduler pool — the service's fixed OS-thread
    /// budget for tuning workflows, independent of how many jobs run.
    pub fn worker_count(&self) -> usize {
        self.scheduler.worker_count()
    }

    /// Tuning jobs submitted and not yet finished (both planes).
    pub fn running_jobs(&self) -> usize {
        self.scheduler.running_jobs()
            + self.remote.as_ref().map(|r| r.running_jobs()).unwrap_or(0)
    }

    /// Shared metadata store (read-only use recommended).
    pub fn store(&self) -> Arc<MetadataStore> {
        Arc::clone(&self.store)
    }

    /// Shared metrics service.
    pub fn metrics(&self) -> Arc<MetricsService> {
        Arc::clone(&self.metrics)
    }

    /// One typed, JSON-serializable view of **every** metric this
    /// service exports (DESIGN.md §15): the per-instance registries of
    /// the store (`store.*`), metrics sink (`metrics.*`), local
    /// scheduler (`scheduler.*`), WAL (`wal.*`, when durable) and
    /// remote pool (`leader.*`, when attached), plus the service-level
    /// API/availability counters (`api.*`), recovery-on-open stats
    /// (`recovery.*`) and trace-sink health (`telemetry.trace_minted` /
    /// `telemetry.trace_dropped` — the latter counts events the bounded
    /// 65 536-event ring overwrote, so ring overflow is never silent).
    /// Backs `amt stats` and the bench harness's histogram emission.
    pub fn telemetry_snapshot(&self) -> TelemetrySnapshot {
        let rs = self.recovery_stats;
        let counter = |name: &str, v: u64| MetricSnapshot {
            name: name.to_string(),
            value: MetricValue::Counter(v),
        };
        let service = vec![
            counter("api.calls", self.api_calls.load(Ordering::Relaxed)),
            counter("api.errors", self.api_errors.load(Ordering::Relaxed)),
            counter("recovery.fast_resumed", rs.fast_resumed as u64),
            counter("recovery.scratch_resumed", rs.scratch_resumed as u64),
            counter("recovery.replayed_proposals", rs.replayed_proposals),
            counter("telemetry.trace_minted", telemetry::trace::minted()),
            counter("telemetry.trace_dropped", telemetry::trace::dropped()),
        ];
        let mut parts = vec![
            service,
            self.store.telemetry_metrics(),
            self.metrics.telemetry_metrics(),
            self.scheduler.telemetry_metrics(),
        ];
        if let Some(wal) = &self.wal {
            parts.push(wal.telemetry_metrics());
        }
        if let Some(remote) = &self.remote {
            parts.push(remote.telemetry_metrics());
        }
        TelemetrySnapshot::from_parts(parts)
    }

    /// Drain the process-global slice-lifecycle trace ring (oldest
    /// first, destructive). `amt trace <job>` and post-run analysis
    /// consume this; tests sharing the process should prefer
    /// [`AmtService::traces_for`].
    pub fn drain_traces(&self) -> Vec<telemetry::trace::TraceEvent> {
        telemetry::trace::drain()
    }

    /// Non-destructive view of one job's trace events, oldest first
    /// (propose → dispatch → worker_poll → delta_apply → group_commit →
    /// outcome for a distributed job).
    pub fn traces_for(&self, job: &str) -> Vec<telemetry::trace::TraceEvent> {
        telemetry::trace::for_job(job)
    }

    fn count_call(&self) {
        self.api_calls.fetch_add(1, Ordering::Relaxed);
    }

    fn fail<T>(&self, e: ApiError) -> Result<T, ApiError> {
        self.api_errors.fetch_add(1, Ordering::Relaxed);
        Err(e)
    }

    /// Resolve warm-start parents from the store into transfer observations.
    fn resolve_parents_for(
        &self,
        request: &TuningJobRequest,
        sign: f64,
        child_space: &crate::space::SearchSpace,
    ) -> Result<Vec<Observation>, ApiError> {
        if request.warm_start_parents.is_empty() {
            return Ok(Vec::new());
        }
        let mut parents = Vec::new();
        for pname in &request.warm_start_parents {
            // parent tuning job must exist and be terminal
            let Some((_, job)) = self.store.get("tuning_jobs", pname) else {
                return self.fail(ApiError::BadParent(pname.clone()));
            };
            let pobj_name = job
                .get("request")
                .and_then(|r| r.get("objective"))
                .and_then(Json::as_str)
                .unwrap_or(&request.objective)
                .to_string();
            let pspace = objective_by_name(&pobj_name)
                .map(|o| o.space())
                .unwrap_or_else(|| child_space.clone());
            // paginated scan: bounded pages instead of one whole-prefix
            // clone under the store's shard locks
            let mut observations = Vec::new();
            let prefix = format!("{pname}-train-");
            let mut cursor: Option<String> = None;
            loop {
                let page =
                    self.store.scan_page("training_jobs", &prefix, cursor.as_deref(), SCAN_PAGE);
                let Some((last_key, _)) = page.last() else { break };
                // a partial page means the prefix is exhausted — no need
                // for a follow-up call that would come back empty
                let exhausted = page.len() < SCAN_PAGE;
                cursor = Some(last_key.clone());
                for (_, rec) in page {
                    let Some(vj) = rec.get("final_value") else { continue };
                    let Some(v) = vj.as_f64() else { continue };
                    let Some(cfg) = rec.get("config").and_then(config_from_json) else {
                        continue;
                    };
                    // coerce numeric strings back into the parent space types
                    let cfg = pspace.clamp(&cfg);
                    observations.push(Observation { config: cfg, value: sign * v });
                }
                if exhausted {
                    break;
                }
            }
            if observations.is_empty() {
                return self.fail(ApiError::BadParent(pname.clone()));
            }
            parents.push(ParentJob { name: pname.clone(), space: pspace, observations });
        }
        Ok(transfer(&parents, child_space, &TransferOptions::default()))
    }

    /// `CreateHyperParameterTuningJob`: validate, persist, start the
    /// asynchronous workflow. Returns the job name (stand-in for the ARN).
    pub fn create_tuning_job(&self, request: TuningJobRequest) -> Result<String, ApiError> {
        self.count_call();
        if let Err(e) = request.validate() {
            return self.fail(ApiError::Validation(e.to_string()));
        }
        let objective: Arc<dyn crate::objectives::Objective> =
            objective_by_name(&request.objective).expect("validated").into();
        self.create_with_objective(request, objective, true)
    }

    /// Tune a *custom algorithm* (the paper: "AMT can be used with built-in
    /// algorithms, custom algorithms, and ... pre-built containers"): same
    /// workflow, caller-supplied objective. The request's `objective` field
    /// is treated as a label; validation of the other fields still applies.
    pub fn create_custom_tuning_job(
        &self,
        request: TuningJobRequest,
        objective: Arc<dyn crate::objectives::Objective>,
    ) -> Result<String, ApiError> {
        self.count_call();
        if let Err(e) = request.validate_with_custom_objective() {
            return self.fail(ApiError::Validation(e.to_string()));
        }
        // a custom objective only exists in this process: never remote
        self.create_with_objective(request, objective, false)
    }

    fn create_with_objective(
        &self,
        request: TuningJobRequest,
        objective: Arc<dyn crate::objectives::Objective>,
        remote_ok: bool,
    ) -> Result<String, ApiError> {
        if self.scheduler.contains(&request.name)
            || self.remote.as_ref().is_some_and(|r| r.contains(&request.name))
            || self.store.get("tuning_jobs", &request.name).is_some()
        {
            return self.fail(ApiError::AlreadyExists(request.name));
        }

        let sign = if objective.minimize() { 1.0 } else { -1.0 };
        // the persisted `warm_start` row is authoritative when present
        // (a resume re-entering the create path, or a reseed that kept
        // the row): reuse it instead of re-running the paginated parent
        // scans — the observations are exactly what the original create
        // computed, which is also what resolution would re-produce
        let transferred = match self
            .store
            .get("warm_start", &request.name)
            .filter(|_| !request.warm_start_parents.is_empty())
            .and_then(|(_, j)| observations_from_json(j.get("observations")?))
        {
            Some(obs) => obs,
            None => self.resolve_parents_for(&request, sign, &objective.space())?,
        };
        self.create_prepared(request, objective, transferred, remote_ok)
    }

    /// Final leg of job creation, with the warm-start transfer
    /// observations already resolved. They are persisted to the
    /// `warm_start` table *before* the job record, so recovery re-enters
    /// here with exactly the observations the original create computed —
    /// a resumed warm-start child never re-resolves against parents that
    /// may themselves still be mid-replay.
    fn create_prepared(
        &self,
        request: TuningJobRequest,
        objective: Arc<dyn crate::objectives::Objective>,
        transferred: Vec<Observation>,
        remote_ok: bool,
    ) -> Result<String, ApiError> {
        let transfer_json = if transferred.is_empty() {
            None
        } else {
            Some(observations_to_json(&transferred))
        };

        // mint the job's lifecycle trace id at submission (the remote
        // plane's register() re-mint is an idempotent no-op)
        telemetry::trace::ensure_trace(&request.name);

        // registry-objective jobs dispatch to the remote plane when one
        // is attached AND a live worker runs a compatible surrogate
        // backend (mixed-backend fleets must not evaluate this job on a
        // different backend — bit-consistency); otherwise fall through
        // to the local plane. Same reserve → persist → activate
        // discipline either way, but the worker rebuilds the actor from
        // the shipped request instead of receiving one built here.
        if remote_ok {
            if let Some(remote) = &self.remote {
                debug_assert!(
                    objective_by_name(&request.objective).is_some(),
                    "remote_ok implies a registry objective"
                );
                if remote.supports_backend(self.backend.name()) {
                    let spec = RemoteJobSpec {
                        request: request.clone(),
                        platform: self.platform_config.clone(),
                        transfer: transferred,
                        backend: self.backend.name().to_string(),
                    };
                    if !remote.register(spec) {
                        return self.fail(ApiError::AlreadyExists(request.name));
                    }
                    persist_job_seeds(&self.store, &request, transfer_json);
                    remote.activate(&request.name);
                    return Ok(request.name);
                }
            }
        }

        // build the strategy (BO gets the warm-start observations) —
        // the shared construction path remote workers also use
        let strategy: Box<dyn Strategy> = crate::strategies::for_request(
            &request.strategy,
            &objective.space(),
            Arc::clone(&self.backend),
            request.seed,
            transferred,
        )
        .expect("validated strategy");
        let stopping = stopping_by_name(&request.early_stopping).expect("validated");

        let stop_flag = Arc::new(AtomicBool::new(false));
        let actor = JobActor::new(
            request.clone(),
            objective,
            strategy,
            stopping,
            TrainingPlatform::new(self.platform_config.clone(), request.seed),
            Arc::clone(&self.store),
            Arc::clone(&self.metrics),
            Arc::clone(&stop_flag),
        );
        // reserve the name first (atomic duplicate check), then persist the
        // accepted request, then let workers at it — a losing concurrent
        // create never touches the store, and the record is always in the
        // store before the workflow can run
        if !self.scheduler.register(actor, stop_flag) {
            return self.fail(ApiError::AlreadyExists(request.name));
        }
        persist_job_seeds(&self.store, &request, transfer_json);
        self.scheduler.activate(&request.name);
        Ok(request.name)
    }

    /// Block until a tuning job's workflow finishes; returns its outcome.
    ///
    /// Blocks on the job's own condvar (never a service-wide lock), so
    /// concurrent Create/Describe/Stop/wait calls for other jobs proceed
    /// unimpeded while this one waits.
    pub fn wait(&self, name: &str) -> Result<TuningJobOutcome, ApiError> {
        if let Some(outcome) = self.scheduler.wait(name) {
            return Ok(outcome);
        }
        if let Some(remote) = &self.remote {
            if let Some(outcome) = remote.wait(name) {
                return Ok(outcome);
            }
        }
        self.fail(ApiError::NotFound(name.to_string()))
    }

    /// `DescribeHyperParameterTuningJob`.
    pub fn describe_tuning_job(&self, name: &str) -> Result<TuningJobSummary, ApiError> {
        self.count_call();
        let Some((_, job)) = self.store.get("tuning_jobs", name) else {
            return self.fail(ApiError::NotFound(name.to_string()));
        };
        let status = job
            .get("status")
            .and_then(Json::as_str)
            .unwrap_or("Unknown")
            .to_string();
        let mut evaluations = 0;
        let mut best: Option<f64> = None;
        let minimize = job
            .get("request")
            .and_then(|r| r.get("objective"))
            .and_then(Json::as_str)
            .and_then(objective_by_name)
            .map(|o| o.minimize())
            .unwrap_or(true);
        for (_, rec) in self.store.scan("training_jobs", &format!("{name}-train-")) {
            let terminal = matches!(
                rec.get("status").and_then(Json::as_str),
                Some("Completed") | Some("Stopped") | Some("Failed")
            );
            if terminal {
                evaluations += 1;
            }
            if let Some(v) = rec.get("final_value").and_then(Json::as_f64) {
                best = Some(match best {
                    None => v,
                    Some(b) => {
                        if minimize {
                            b.min(v)
                        } else {
                            b.max(v)
                        }
                    }
                });
            }
        }
        Ok(TuningJobSummary { name: name.to_string(), status, evaluations, best_value: best })
    }

    /// `ListHyperParameterTuningJobs` (optionally by name prefix).
    pub fn list_tuning_jobs(&self, prefix: &str) -> Vec<String> {
        self.count_call();
        self.store.list_keys("tuning_jobs", prefix)
    }

    /// `StopHyperParameterTuningJob`: signal the workflow to stop. The
    /// call is asynchronous, like the AWS API, and never blocks on other
    /// jobs — it only flips the target job's stop flag.
    pub fn stop_tuning_job(&self, name: &str) -> Result<(), ApiError> {
        self.count_call();
        if self.scheduler.stop(name)
            || self.remote.as_ref().is_some_and(|r| r.stop(name))
        {
            Ok(())
        } else {
            self.fail(ApiError::NotFound(name.to_string()))
        }
    }

    /// Availability ratio over the service lifetime (§6.5: "API
    /// communication was available ... for the 99.99% of time").
    pub fn availability(&self) -> f64 {
        let calls = self.api_calls.load(Ordering::Relaxed);
        let errors = self.api_errors.load(Ordering::Relaxed);
        if calls == 0 {
            1.0
        } else {
            1.0 - errors as f64 / calls as f64
        }
    }
}

/// Convenience for tests/benches: extract a numeric HP from a config.
pub fn config_num(config: &crate::space::Config, key: &str) -> Option<f64> {
    config.get(key).and_then(Value::as_f64)
}

/// Delete every store record and metric stream a job wrote, so its
/// deterministic replay starts from a clean slate (versions restart at
/// 1, exactly like an uninterrupted run). Shared by recovery-on-open
/// and the distributed leader's worker-death repair — the record/stream
/// namespace layout lives only here. The `{name}-train-` prefixes
/// cannot reach a sibling job's records: job names may not contain
/// `-train-` (request validation), so no other job name is an extension
/// of this prefix.
pub(crate) fn reset_job_records(store: &MetadataStore, metrics: &MetricsService, name: &str) {
    // evaluation-cache entries this job recorded must not survive into
    // its deterministic replay: a replayed evaluation hitting its own
    // pre-crash entry would short-circuit where the original trained,
    // diverging from the uninterrupted timeline. Entries owned by other
    // jobs are untouched. The job record still exists at this point (it
    // is deleted just below), so the objective — and with it the cache
    // key prefix — is recoverable from it.
    if let Some((_, job)) = store.get("tuning_jobs", name) {
        if let Some(obj) = job
            .get("request")
            .and_then(|r| r.get("objective"))
            .and_then(Json::as_str)
        {
            for (key, entry) in store.scan(crate::store::EVAL_CACHE_TABLE, &format!("{obj}|")) {
                if entry.get("owner").and_then(Json::as_str) == Some(name) {
                    store.delete(crate::store::EVAL_CACHE_TABLE, &key);
                }
            }
        }
    }
    store.delete("tuning_jobs", name);
    store.delete("warm_start", name);
    for key in store.list_keys("training_jobs", &format!("{name}-train-")) {
        store.delete("training_jobs", &key);
    }
    metrics.remove_streams(&format!("{name}-train-"));
    metrics.remove_streams(&format!("{name}/"));
}

/// Persist an accepted job's seed records: warm-start observations
/// first (when any), the `InProgress` job record second — any WAL
/// prefix containing the job record also contains the transfer data its
/// recovery needs. The single definition of the job-record shape,
/// shared by `create_prepared` (both planes) and the leader's
/// worker-death reseed.
pub(crate) fn persist_job_seeds(
    store: &MetadataStore,
    request: &TuningJobRequest,
    transfer_json: Option<Json>,
) {
    if let Some(tj) = transfer_json {
        store.put("warm_start", &request.name, Json::obj(vec![("observations", tj)]));
    }
    store.put(
        "tuning_jobs",
        &request.name,
        Json::obj(vec![
            ("status", Json::Str("InProgress".into())),
            ("request", request.to_json()),
        ]),
    );
}

/// Persist a `Failed` terminal job record (recovery that cannot resume,
/// a remote worker rejecting a job, a death with no replacement worker).
pub(crate) fn persist_job_failed(
    store: &MetadataStore,
    name: &str,
    request: Json,
    reason: &str,
) {
    store.put(
        "tuning_jobs",
        name,
        Json::obj(vec![
            ("status", Json::Str("Failed".into())),
            ("request", request),
            ("failure_reason", Json::Str(reason.into())),
        ]),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_request(name: &str, jobs: u32) -> TuningJobRequest {
        TuningJobRequest {
            name: name.into(),
            objective: "branin".into(),
            strategy: "random".into(),
            max_training_jobs: jobs,
            max_parallel_jobs: 2,
            ..Default::default()
        }
    }

    #[test]
    fn create_wait_describe_lifecycle() {
        let svc = AmtService::new(PlatformConfig::noiseless());
        let name = svc.create_tuning_job(quick_request("job-a", 5)).unwrap();
        let outcome = svc.wait(&name).unwrap();
        assert_eq!(outcome.evaluations.len(), 5);
        let d = svc.describe_tuning_job(&name).unwrap();
        assert_eq!(d.status, "Completed");
        assert_eq!(d.evaluations, 5);
        assert!(d.best_value.is_some());
        assert_eq!(svc.list_tuning_jobs("job-"), vec!["job-a"]);
    }

    #[test]
    fn duplicate_names_rejected() {
        let svc = AmtService::new(PlatformConfig::noiseless());
        svc.create_tuning_job(quick_request("dup", 2)).unwrap();
        assert!(matches!(
            svc.create_tuning_job(quick_request("dup", 2)),
            Err(ApiError::AlreadyExists(_))
        ));
        svc.wait("dup").unwrap();
    }

    #[test]
    fn validation_errors_surface() {
        let svc = AmtService::new(PlatformConfig::noiseless());
        let mut r = quick_request("bad", 2);
        r.objective = "nonexistent".into();
        assert!(matches!(svc.create_tuning_job(r), Err(ApiError::Validation(_))));
        assert!(svc.availability() < 1.0);
    }

    #[test]
    fn describe_and_stop_missing_jobs() {
        let svc = AmtService::new(PlatformConfig::noiseless());
        assert!(matches!(svc.describe_tuning_job("ghost"), Err(ApiError::NotFound(_))));
        assert!(matches!(svc.stop_tuning_job("ghost"), Err(ApiError::NotFound(_))));
    }

    #[test]
    fn stop_terminates_early() {
        let svc = AmtService::new(PlatformConfig::noiseless());
        let name = svc
            .create_tuning_job(quick_request("stoppable", 500))
            .unwrap();
        svc.stop_tuning_job(&name).unwrap();
        let outcome = svc.wait(&name).unwrap();
        assert!(outcome.evaluations.len() < 500);
        let d = svc.describe_tuning_job(&name).unwrap();
        assert_eq!(d.status, "Stopped");
    }

    #[test]
    fn warm_start_resolves_parent_from_store() {
        let svc = AmtService::new(PlatformConfig::noiseless());
        svc.create_tuning_job(quick_request("parent", 6)).unwrap();
        svc.wait("parent").unwrap();

        let mut child = quick_request("child", 4);
        child.strategy = "bayesian".into();
        child.warm_start_parents = vec!["parent".into()];
        let name = svc.create_tuning_job(child).unwrap();
        let outcome = svc.wait(&name).unwrap();
        assert_eq!(outcome.evaluations.len(), 4);
    }

    #[test]
    fn warm_start_rejects_unknown_parent() {
        let svc = AmtService::new(PlatformConfig::noiseless());
        let mut r = quick_request("orphan", 2);
        r.strategy = "bayesian".into();
        r.warm_start_parents = vec!["never-existed".into()];
        assert!(matches!(svc.create_tuning_job(r), Err(ApiError::BadParent(_))));
    }

    #[test]
    fn remote_plane_runs_registry_jobs() {
        use crate::distributed::worker::spawn_loopback_worker;
        let mut transports = Vec::new();
        let mut handles = Vec::new();
        for i in 0..2 {
            let (t, _fault, h) = spawn_loopback_worker(&format!("api-{i}"));
            transports.push(t);
            handles.push(h);
        }
        let svc = AmtService::with_remote_workers(PlatformConfig::noiseless(), transports);
        let name = svc.create_tuning_job(quick_request("remote-a", 4)).unwrap();
        let out = svc.wait(&name).unwrap();
        assert_eq!(out.evaluations.len(), 4);
        let d = svc.describe_tuning_job(&name).unwrap();
        assert_eq!(d.status, "Completed");
        assert_eq!(d.evaluations, 4);
        // name uniqueness holds across the remote plane too
        assert!(matches!(
            svc.create_tuning_job(quick_request("remote-a", 2)),
            Err(ApiError::AlreadyExists(_))
        ));
        // stop on the remote plane is reachable through the same API
        svc.create_tuning_job(quick_request("remote-b", 400)).unwrap();
        svc.stop_tuning_job("remote-b").unwrap();
        let out = svc.wait("remote-b").unwrap();
        assert!(out.evaluations.len() < 400);
        drop(svc);
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn concurrent_tuning_jobs_run() {
        let svc = Arc::new(AmtService::new(PlatformConfig::noiseless()));
        for i in 0..4 {
            svc.create_tuning_job(quick_request(&format!("par-{i}"), 3)).unwrap();
        }
        for i in 0..4 {
            let out = svc.wait(&format!("par-{i}")).unwrap();
            assert_eq!(out.evaluations.len(), 3);
        }
        assert_eq!(svc.list_tuning_jobs("par-").len(), 4);
        assert_eq!(svc.availability(), 1.0);
    }
}
