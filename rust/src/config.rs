//! Tuning-job request/record types and their JSON wire format — the shapes
//! the Create/Describe/List/Stop APIs exchange (§3.2).
//!
//! Mirrors the SageMaker API surface at the granularity this reproduction
//! needs: a `TuningJobRequest` names a workload (objective), a selection
//! strategy, resource limits, early-stopping and warm-start settings.

use crate::json::Json;

/// Request payload of `CreateHyperParameterTuningJob`.
#[derive(Clone, Debug, PartialEq)]
pub struct TuningJobRequest {
    /// Unique tuning-job name.
    pub name: String,
    /// Workload to tune (a [`crate::objectives`] registry name).
    pub objective: String,
    /// Selection strategy: "bayesian" | "random" | "grid" | "sobol".
    pub strategy: String,
    /// Budget: total hyperparameter evaluations.
    pub max_training_jobs: u32,
    /// Parallelism: simultaneous training jobs L (§4.4).
    pub max_parallel_jobs: u32,
    /// Early stopping: "off" | "median" | "linear" | "asha" (§5.2).
    pub early_stopping: String,
    /// EC2 instances per training job (>1 ⇒ distributed mode).
    pub instance_count: u32,
    /// RNG seed for the whole tuning job.
    pub seed: u64,
    /// Parent tuning jobs to warm start from (§5.3).
    pub warm_start_parents: Vec<String>,
    /// Per-evaluation retry budget for failed training jobs (§3.3).
    pub max_retries_per_job: u32,
    /// Fair-share weight of this tenant on the multi-tenant scheduler
    /// (Autotune-style): under contention a weight-w job drains ~w× the
    /// poll slices of a weight-1 job. 1 = the default equal share.
    pub tenant_weight: u32,
    /// Tenant identity for in-flight quota accounting. Empty (the
    /// default) = no shared quota: the job is accounted on its own and
    /// scheduling order is exactly the legacy weighted-heap order.
    pub tenant: String,
    /// Cap on *concurrent* poll slices across all jobs of this tenant
    /// (on top of the virtual-time discount `tenant_weight` applies):
    /// a quota-q tenant never occupies more than q pool workers at
    /// once. 0 (the default) = unlimited, preserving legacy ordering.
    /// Jobs sharing a `tenant` should carry the same `max_in_flight`
    /// (the most recently registered non-zero value wins).
    pub max_in_flight: u32,
    /// Enable the speculative proposal pipeline (DESIGN.md §17): while
    /// parallel slots are full, the strategy pre-computes the next
    /// proposal against a constant-liar fantasy observation in the
    /// scheduler's idle tail. Off (the default) preserves the exact
    /// synchronous proposal path; on, outcomes are still bit-identical
    /// (commits only happen when provably byte-equivalent).
    pub speculative: bool,
    /// Enable the cross-job evaluation cache (DESIGN.md §17): proposals
    /// whose typed-config key already has a recorded outcome for this
    /// objective short-circuit the training platform and replay the
    /// recorded metric series. Off by default — cached outcomes arrive
    /// instantly, which changes the virtual timeline versus an uncached
    /// run.
    pub eval_cache: bool,
}

impl Default for TuningJobRequest {
    fn default() -> Self {
        TuningJobRequest {
            name: "tuning-job".into(),
            objective: "branin".into(),
            strategy: "bayesian".into(),
            max_training_jobs: 20,
            max_parallel_jobs: 1,
            early_stopping: "off".into(),
            instance_count: 1,
            seed: 0,
            warm_start_parents: Vec::new(),
            max_retries_per_job: 2,
            tenant_weight: 1,
            tenant: String::new(),
            max_in_flight: 0,
            speculative: false,
            eval_cache: false,
        }
    }
}

/// Request validation failure (the API's synchronous 4xx path).
#[derive(Debug, PartialEq, Eq)]
pub enum ValidationError {
    /// Name empty or too long.
    BadName(String),
    /// Unknown objective/workload.
    UnknownObjective(String),
    /// Unknown strategy.
    UnknownStrategy(String),
    /// Unknown early-stopping mode.
    UnknownEarlyStopping(String),
    /// Limits out of range.
    BadLimits(String),
}

impl std::fmt::Display for ValidationError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}

impl std::error::Error for ValidationError {}

/// Known strategy names.
pub const STRATEGIES: &[&str] = &["bayesian", "bo", "random", "grid", "sobol"];
/// Known early-stopping modes.
pub const EARLY_STOPPING_MODES: &[&str] = &["off", "median", "linear", "asha"];

impl TuningJobRequest {
    /// Validate against the objective registry and limits.
    pub fn validate(&self) -> Result<(), ValidationError> {
        if crate::objectives::by_name(&self.objective).is_none() {
            return Err(ValidationError::UnknownObjective(self.objective.clone()));
        }
        self.validate_with_custom_objective()
    }

    /// Validation for custom-algorithm jobs (§1: "AMT can be used with
    /// built-in algorithms, custom algorithms ..."): everything except the
    /// built-in objective-registry membership check.
    pub fn validate_with_custom_objective(&self) -> Result<(), ValidationError> {
        // `-train-` is the reserved separator for per-training-job record
        // keys and metric streams (`{job}-train-NNNN…`): forbidding it in
        // job names keeps those prefix namespaces unambiguous, which
        // crash recovery relies on when it resets a job's partial state.
        if self.name.is_empty() || self.name.len() > 64 || self.name.contains("-train-") {
            return Err(ValidationError::BadName(self.name.clone()));
        }
        if !STRATEGIES.contains(&self.strategy.as_str()) {
            return Err(ValidationError::UnknownStrategy(self.strategy.clone()));
        }
        if !EARLY_STOPPING_MODES.contains(&self.early_stopping.as_str()) {
            return Err(ValidationError::UnknownEarlyStopping(self.early_stopping.clone()));
        }
        if self.max_training_jobs == 0 || self.max_training_jobs > 10_000 {
            return Err(ValidationError::BadLimits("max_training_jobs".into()));
        }
        if self.max_parallel_jobs == 0 || self.max_parallel_jobs > 100 {
            return Err(ValidationError::BadLimits("max_parallel_jobs".into()));
        }
        if self.instance_count == 0 || self.instance_count > 128 {
            return Err(ValidationError::BadLimits("instance_count".into()));
        }
        if self.tenant_weight == 0 || self.tenant_weight > 100 {
            return Err(ValidationError::BadLimits("tenant_weight".into()));
        }
        if self.tenant.len() > 64 {
            return Err(ValidationError::BadLimits("tenant".into()));
        }
        if self.max_in_flight > 1000 {
            return Err(ValidationError::BadLimits("max_in_flight".into()));
        }
        Ok(())
    }

    /// JSON wire form.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("objective", Json::Str(self.objective.clone())),
            ("strategy", Json::Str(self.strategy.clone())),
            ("max_training_jobs", Json::Num(self.max_training_jobs as f64)),
            ("max_parallel_jobs", Json::Num(self.max_parallel_jobs as f64)),
            ("early_stopping", Json::Str(self.early_stopping.clone())),
            ("instance_count", Json::Num(self.instance_count as f64)),
            ("seed", Json::Num(self.seed as f64)),
            (
                "warm_start_parents",
                Json::Arr(
                    self.warm_start_parents.iter().map(|p| Json::Str(p.clone())).collect(),
                ),
            ),
            ("max_retries_per_job", Json::Num(self.max_retries_per_job as f64)),
            ("tenant_weight", Json::Num(self.tenant_weight as f64)),
            ("tenant", Json::Str(self.tenant.clone())),
            ("max_in_flight", Json::Num(self.max_in_flight as f64)),
            ("speculative", Json::Bool(self.speculative)),
            ("eval_cache", Json::Bool(self.eval_cache)),
        ])
    }

    /// Parse the JSON wire form (missing fields take defaults).
    pub fn from_json(j: &Json) -> Option<TuningJobRequest> {
        let d = TuningJobRequest::default();
        let get_str = |k: &str, dv: &str| {
            j.get(k).and_then(Json::as_str).map(String::from).unwrap_or_else(|| dv.into())
        };
        let get_u32 =
            |k: &str, dv: u32| j.get(k).and_then(Json::as_i64).map(|v| v as u32).unwrap_or(dv);
        Some(TuningJobRequest {
            name: j.get("name")?.as_str()?.to_string(),
            objective: get_str("objective", &d.objective),
            strategy: get_str("strategy", &d.strategy),
            max_training_jobs: get_u32("max_training_jobs", d.max_training_jobs),
            max_parallel_jobs: get_u32("max_parallel_jobs", d.max_parallel_jobs),
            early_stopping: get_str("early_stopping", &d.early_stopping),
            instance_count: get_u32("instance_count", d.instance_count),
            seed: j.get("seed").and_then(Json::as_i64).map(|v| v as u64).unwrap_or(d.seed),
            warm_start_parents: j
                .get("warm_start_parents")
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter().filter_map(|v| v.as_str().map(String::from)).collect()
                })
                .unwrap_or_default(),
            max_retries_per_job: get_u32("max_retries_per_job", d.max_retries_per_job),
            tenant_weight: get_u32("tenant_weight", d.tenant_weight),
            tenant: get_str("tenant", &d.tenant),
            max_in_flight: get_u32("max_in_flight", d.max_in_flight),
            // absent on pre-pipeline wire payloads ⇒ both features off
            speculative: j.get("speculative").and_then(Json::as_bool).unwrap_or(false),
            eval_cache: j.get("eval_cache").and_then(Json::as_bool).unwrap_or(false),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_request_is_valid() {
        assert_eq!(TuningJobRequest::default().validate(), Ok(()));
    }

    #[test]
    fn validation_catches_errors() {
        let mut r = TuningJobRequest::default();
        r.name = String::new();
        assert!(matches!(r.validate(), Err(ValidationError::BadName(_))));

        // the training-record namespace separator is reserved
        let mut r = TuningJobRequest::default();
        r.name = "sneaky-train-0000".into();
        assert!(matches!(r.validate(), Err(ValidationError::BadName(_))));

        let mut r = TuningJobRequest::default();
        r.objective = "nope".into();
        assert!(matches!(r.validate(), Err(ValidationError::UnknownObjective(_))));

        let mut r = TuningJobRequest::default();
        r.strategy = "nope".into();
        assert!(matches!(r.validate(), Err(ValidationError::UnknownStrategy(_))));

        let mut r = TuningJobRequest::default();
        r.early_stopping = "nope".into();
        assert!(matches!(r.validate(), Err(ValidationError::UnknownEarlyStopping(_))));

        let mut r = TuningJobRequest::default();
        r.max_parallel_jobs = 0;
        assert!(matches!(r.validate(), Err(ValidationError::BadLimits(_))));

        let mut r = TuningJobRequest::default();
        r.instance_count = 1000;
        assert!(matches!(r.validate(), Err(ValidationError::BadLimits(_))));

        let mut r = TuningJobRequest::default();
        r.tenant_weight = 0;
        assert!(matches!(r.validate(), Err(ValidationError::BadLimits(_))));

        let mut r = TuningJobRequest::default();
        r.max_in_flight = 5000;
        assert!(matches!(r.validate(), Err(ValidationError::BadLimits(_))));
    }

    #[test]
    fn json_roundtrip() {
        let mut r = TuningJobRequest::default();
        r.name = "my-job".into();
        r.warm_start_parents = vec!["parent-1".into(), "parent-2".into()];
        r.seed = 77;
        r.tenant_weight = 3;
        r.tenant = "acme".into();
        r.max_in_flight = 2;
        r.speculative = true;
        r.eval_cache = true;
        let j = r.to_json();
        let back = TuningJobRequest::from_json(&crate::json::parse(&j.to_string()).unwrap())
            .unwrap();
        assert_eq!(r, back);
    }

    #[test]
    fn from_json_applies_defaults() {
        let j = crate::json::parse(r#"{"name": "x"}"#).unwrap();
        let r = TuningJobRequest::from_json(&j).unwrap();
        assert_eq!(r.strategy, "bayesian");
        assert_eq!(r.max_training_jobs, 20);
        // and a nameless request is rejected
        let j = crate::json::parse(r#"{"objective": "branin"}"#).unwrap();
        assert!(TuningJobRequest::from_json(&j).is_none());
    }
}
