//! Multi-tenant scheduler integration: a spike of tuning jobs multiplexed
//! over the bounded worker pool, with concurrent Create/Describe/Stop/wait
//! traffic — the §3.2/§6.5 service behavior the thread-per-job design
//! could not provide. Asserts: no deadlock (the test terminating *is* the
//! property), correct terminal statuses, per-key store version
//! monotonicity under concurrent observation, a bounded OS-thread budget,
//! and scheduler outcomes bit-identical to the direct single-tenant
//! runner.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use amt::api::AmtService;
use amt::config::TuningJobRequest;
use amt::coordinator::{stopping_by_name, TuningJobRunner};
use amt::gp::NativeBackend;
use amt::metrics::MetricsService;
use amt::platform::{PlatformConfig, TrainingPlatform};
use amt::scheduler::SchedulerConfig;
use amt::store::MetadataStore;

fn spike_request(i: usize, evals: u32) -> TuningJobRequest {
    TuningJobRequest {
        name: format!("spike-{i:03}"),
        objective: "branin".into(),
        // cheap strategies keep 64 jobs fast; the scheduling machinery is
        // identical for BO
        strategy: if i % 2 == 0 { "random" } else { "sobol" }.into(),
        max_training_jobs: evals,
        max_parallel_jobs: 3,
        seed: i as u64,
        ..Default::default()
    }
}

#[test]
fn spike_of_64_jobs_on_bounded_pool() {
    let svc = Arc::new(AmtService::new(PlatformConfig::noiseless()));
    let n = 64usize;

    // the pool is fixed before any job exists and stays well below the
    // job count: 64 tuning jobs must share ≤ min(cores, 16) workers
    assert!(svc.worker_count() <= amt::parallel::max_threads().min(16));
    assert!(svc.worker_count() >= 1);

    for i in 0..n {
        svc.create_tuning_job(spike_request(i, 3)).unwrap();
        // interleave synchronous API load during the spike
        if i % 5 == 0 {
            let _ = svc.describe_tuning_job(&format!("spike-{:03}", i / 2));
            let _ = svc.list_tuning_jobs("spike-");
        }
    }

    // stop every 8th job mid-flight
    for i in (0..n).step_by(8) {
        svc.stop_tuning_job(&format!("spike-{i:03}")).unwrap();
    }

    // concurrent store observers: per-key versions must be monotone while
    // the worker pool writes on behalf of all 64 jobs
    let done = Arc::new(AtomicBool::new(false));
    let observers: Vec<_> = (0..3usize)
        .map(|o| {
            let svc = Arc::clone(&svc);
            let done = Arc::clone(&done);
            std::thread::spawn(move || {
                let store = svc.store();
                let mut last: Vec<u64> = vec![0; 64];
                while !done.load(Ordering::Relaxed) {
                    for (i, slot) in last.iter_mut().enumerate() {
                        if i % 3 != o {
                            continue;
                        }
                        if let Some((ver, _)) = store.get("tuning_jobs", &format!("spike-{i:03}"))
                        {
                            assert!(
                                ver >= *slot,
                                "version regressed for spike-{i:03}: {ver} < {slot}"
                            );
                            *slot = ver;
                        }
                    }
                    std::thread::yield_now();
                }
            })
        })
        .collect();

    // wait for every job from several threads at once (wait() must not
    // serialize behind a service-wide lock)
    let waiters: Vec<_> = (0..4)
        .map(|w| {
            let svc = Arc::clone(&svc);
            std::thread::spawn(move || {
                for i in (w..64).step_by(4) {
                    let out = svc.wait(&format!("spike-{i:03}")).unwrap();
                    assert!(out.evaluations.len() <= 3);
                }
            })
        })
        .collect();
    for w in waiters {
        w.join().unwrap();
    }
    done.store(true, Ordering::Relaxed);
    for o in observers {
        o.join().unwrap();
    }

    // every job reached a correct terminal status
    for i in 0..n {
        let d = svc.describe_tuning_job(&format!("spike-{i:03}")).unwrap();
        assert!(
            ["Completed", "Stopped"].contains(&d.status.as_str()),
            "spike-{i:03} ended as {}",
            d.status
        );
        if i % 8 != 0 {
            // non-stopped jobs ran their full budget
            assert_eq!(d.status, "Completed", "spike-{i:03}");
            assert_eq!(d.evaluations, 3, "spike-{i:03}");
        }
    }
    assert_eq!(svc.list_tuning_jobs("spike-").len(), n);
    assert_eq!(svc.running_jobs(), 0);
    assert_eq!(svc.availability(), 1.0);
}

#[test]
fn wait_does_not_block_other_api_calls() {
    // Under the old thread-per-job service, wait() joined the runner thread
    // while holding the service-wide jobs mutex, so this test deadlocked:
    // the waiter held the lock until "slow" finished, and stop_tuning_job
    // needed the lock to ever finish it.
    let svc = Arc::new(AmtService::new(PlatformConfig::noiseless()));
    let slow = TuningJobRequest {
        name: "slow".into(),
        objective: "branin".into(),
        strategy: "random".into(),
        max_training_jobs: 10_000,
        max_parallel_jobs: 1,
        ..Default::default()
    };
    svc.create_tuning_job(slow).unwrap();

    let waiter = {
        let svc = Arc::clone(&svc);
        std::thread::spawn(move || svc.wait("slow").unwrap())
    };

    // while "slow" is being waited on, the synchronous APIs stay live
    let mut quick = spike_request(0, 2);
    quick.name = "quick".into();
    svc.create_tuning_job(quick).unwrap();
    assert_eq!(svc.wait("quick").unwrap().evaluations.len(), 2);
    assert!(svc.describe_tuning_job("slow").is_ok());

    // and Stop is what ends the waited-on job
    svc.stop_tuning_job("slow").unwrap();
    let out = waiter.join().unwrap();
    assert!(out.evaluations.len() < 10_000);
    assert_eq!(svc.describe_tuning_job("slow").unwrap().status, "Stopped");
}

#[test]
fn scheduler_outcome_bit_identical_to_direct_runner() {
    // acceptance criterion: seeded single-job outcomes through the
    // multi-tenant scheduler match the pre-refactor run-to-completion
    // runner bit for bit — noisy platform config included
    let request = TuningJobRequest {
        name: "bitident".into(),
        objective: "branin".into(),
        strategy: "random".into(),
        max_training_jobs: 8,
        max_parallel_jobs: 3,
        seed: 1234,
        ..Default::default()
    };
    let objective: Arc<dyn amt::objectives::Objective> =
        amt::objectives::by_name("branin").unwrap().into();
    let strategy = amt::strategies::by_name(
        "random",
        &objective.space(),
        Arc::new(NativeBackend),
        request.seed,
    )
    .unwrap();
    let direct = TuningJobRunner::new(
        request.clone(),
        Arc::clone(&objective),
        strategy,
        stopping_by_name("off").unwrap(),
        TrainingPlatform::new(PlatformConfig::default(), request.seed),
        Arc::new(MetadataStore::new()),
        Arc::new(MetricsService::new()),
        Arc::new(AtomicBool::new(false)),
    )
    .run();

    // tiny pool + tiny batch: maximum interleaving with other tenants
    let svc = AmtService::with_options(
        PlatformConfig::default(),
        Arc::new(NativeBackend),
        SchedulerConfig { workers: 2, batch_steps: 3 },
    );
    for i in 0..6 {
        svc.create_tuning_job(spike_request(i, 2)).unwrap();
    }
    svc.create_tuning_job(request).unwrap();
    let pooled = svc.wait("bitident").unwrap();

    assert_eq!(direct.evaluations.len(), pooled.evaluations.len());
    for (a, b) in direct.evaluations.iter().zip(&pooled.evaluations) {
        assert_eq!(a.training_job_name, b.training_job_name);
        assert_eq!(a.config, b.config);
        assert_eq!(a.curve.len(), b.curve.len());
        for (x, y) in a.curve.iter().zip(&b.curve) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(
            a.final_value.map(f64::to_bits),
            b.final_value.map(f64::to_bits)
        );
        assert_eq!(a.status, b.status);
        assert_eq!(a.stopped_early, b.stopped_early);
        assert_eq!(a.attempts, b.attempts);
        assert_eq!(a.submitted_at.to_bits(), b.submitted_at.to_bits());
        assert_eq!(a.ended_at.to_bits(), b.ended_at.to_bits());
    }
    assert_eq!(direct.total_seconds.to_bits(), pooled.total_seconds.to_bits());
    assert_eq!(
        direct.total_billable_seconds.to_bits(),
        pooled.total_billable_seconds.to_bits()
    );
    assert_eq!(direct.retries, pooled.retries);
    assert_eq!(direct.status, pooled.status);
}

#[test]
fn stress_create_stop_wait_interleaving() {
    // rapid-fire create/stop/wait cycles across a small pool: exercises
    // slot reuse, re-queueing and the stop path racing job completion
    let svc = Arc::new(AmtService::with_options(
        PlatformConfig::noiseless(),
        Arc::new(NativeBackend),
        SchedulerConfig { workers: 3, batch_steps: 16 },
    ));
    for round in 0..4u64 {
        for i in 0..16u64 {
            let r = TuningJobRequest {
                name: format!("stress-{round}-{i}"),
                objective: "branin".into(),
                strategy: "random".into(),
                max_training_jobs: if i % 2 == 0 { 2 } else { 200 },
                max_parallel_jobs: 2,
                seed: round * 100 + i,
                ..Default::default()
            };
            svc.create_tuning_job(r).unwrap();
        }
        // stop the long ones immediately — may race their first events
        for i in (1..16u64).step_by(2) {
            svc.stop_tuning_job(&format!("stress-{round}-{i}")).unwrap();
        }
        for i in 0..16u64 {
            let name = format!("stress-{round}-{i}");
            let out = svc.wait(&name).unwrap();
            if i % 2 == 0 {
                assert_eq!(out.evaluations.len(), 2);
            } else {
                assert!(out.evaluations.len() <= 200);
            }
            let status = svc.describe_tuning_job(&name).unwrap().status;
            assert!(["Completed", "Stopped"].contains(&status.as_str()), "{name}: {status}");
        }
    }
    assert_eq!(svc.running_jobs(), 0);
}
