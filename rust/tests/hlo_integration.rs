//! Cross-backend integration tests: the AOT HLO artifacts (Pallas kernel +
//! JAX graphs executed through PJRT) must reproduce the native Rust GP
//! numerics, and the full BO stack must run on the HLO backend.
//!
//! These tests require `make artifacts` to have been run; they are skipped
//! (with a notice) when `artifacts/manifest.json` is absent so `cargo test`
//! stays usable in a fresh checkout.

use std::sync::Arc;

use amt::gp::{nll, Dataset, GpModel, NativeBackend, SurrogateBackend, Theta};
use amt::rng::Rng;
use amt::runtime::{HloBackend, HloRuntime};

fn runtime_or_skip() -> Option<Arc<HloRuntime>> {
    match HloRuntime::open("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP hlo integration test (run `make artifacts`): {e}");
            None
        }
    }
}

fn random_data(n: usize, d: usize, seed: u64) -> (Dataset, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let x = Dataset::from_fn(n, d, |_, _| rng.uniform());
    let y: Vec<f64> = x
        .rows()
        .map(|p| (4.0 * p[0]).sin() + 0.5 * p[d - 1] + 0.02 * rng.normal())
        .collect();
    (x, y)
}

fn warped_theta(d: usize) -> Theta {
    let mut t = Theta::default_for_dim(d);
    for j in 0..d {
        t.log_ls[j] = (0.3 + 0.1 * j as f64).ln();
        t.log_wa[j] = 0.2;
        t.log_wb[j] = -0.15;
    }
    t
}

#[test]
fn gram_matches_native_across_buckets_and_dims() {
    let Some(rt) = runtime_or_skip() else { return };
    let hlo = HloBackend::artifacts_only(rt); // exercise the HLO gram path
    for &(n, d) in &[(5usize, 2usize), (16, 4), (40, 8), (100, 3)] {
        let (x, _) = random_data(n, d, (n * d) as u64);
        let theta = warped_theta(d);
        let k_native = NativeBackend.gram(&x, &theta);
        let k_hlo = hlo.gram(&x, &theta);
        assert_eq!((k_hlo.rows, k_hlo.cols), (n, n));
        let diff = k_native.max_abs_diff(&k_hlo);
        assert!(diff < 5e-4, "n={n} d={d}: max |Δ| = {diff}");
    }
    assert_eq!(
        hlo.native_fallbacks.load(std::sync::atomic::Ordering::Relaxed),
        0,
        "all shapes above must run on the HLO path"
    );
}

#[test]
fn posterior_scores_match_native() {
    let Some(rt) = runtime_or_skip() else { return };
    let hlo = HloBackend::new(rt);
    let (x, y_raw) = random_data(30, 4, 7);
    let (m, s) = amt::gp::normalization(&y_raw);
    let y: Vec<f64> = y_raw.iter().map(|v| (v - m) / s).collect();
    let theta = warped_theta(4);

    // fit via the native path, score via both backends
    let model = GpModel::fit(&NativeBackend, &x, &y, vec![theta]).unwrap();
    let post = &model.posteriors[0];

    let mut rng = Rng::new(9);
    let cands = Dataset::from_fn(300, 4, |_, _| rng.uniform());
    let y_best = model.y_best_norm;

    let native = NativeBackend.posterior_scores(post, &cands, y_best);
    let execs_before = hlo.runtime().executions.load(std::sync::atomic::Ordering::Relaxed);
    let fast = hlo.posterior_scores(post, &cands, y_best);
    let execs_after = hlo.runtime().executions.load(std::sync::atomic::Ordering::Relaxed);
    // guard against silent native fallback (e.g. unparseable artifact)
    assert!(execs_after > execs_before, "posterior_ei artifact did not execute");
    assert_eq!(
        hlo.native_fallbacks.load(std::sync::atomic::Ordering::Relaxed),
        0,
        "posterior scoring fell back to the native path"
    );
    assert_eq!(native.len(), fast.len());
    for (i, (a, b)) in native.iter().zip(&fast).enumerate() {
        assert!((a.mu - b.mu).abs() < 2e-3, "mu[{i}]: {} vs {}", a.mu, b.mu);
        assert!((a.var - b.var).abs() < 2e-3, "var[{i}]: {} vs {}", a.var, b.var);
        assert!((a.ei - b.ei).abs() < 2e-3, "ei[{i}]: {} vs {}", a.ei, b.ei);
    }
}

#[test]
fn nll_agrees_between_backends() {
    let Some(rt) = runtime_or_skip() else { return };
    let hlo = HloBackend::artifacts_only(rt);
    let (x, y_raw) = random_data(24, 5, 3);
    let (m, s) = amt::gp::normalization(&y_raw);
    let y: Vec<f64> = y_raw.iter().map(|v| (v - m) / s).collect();
    let theta = warped_theta(5);
    let a = nll(&NativeBackend, &x, &y, &theta).unwrap();
    let b = nll(&hlo, &x, &y, &theta).unwrap();
    assert!((a - b).abs() < 0.05, "nll {a} vs {b}");
}

#[test]
fn full_bo_loop_runs_on_hlo_backend() {
    let Some(rt) = runtime_or_skip() else { return };
    let backend: Arc<dyn SurrogateBackend> = Arc::new(HloBackend::new(Arc::clone(&rt)));
    use amt::acquisition::AcquisitionConfig;
    use amt::space::{continuous, Scaling, SearchSpace};
    use amt::strategies::{BayesianOptimization, BoConfig, GphpMode, Observation, Strategy};

    let space = SearchSpace::new(vec![
        continuous("a", 0.0, 1.0, Scaling::Linear),
        continuous("b", 0.0, 1.0, Scaling::Linear),
    ])
    .unwrap();
    let mut bo = BayesianOptimization::new(
        space.clone(),
        backend,
        BoConfig {
            init_random: 4,
            gphp: GphpMode::EmpiricalBayes { restarts: 1 },
            acq: AcquisitionConfig { num_anchors: 64, num_local_starts: 1, ..Default::default() },
            ..Default::default()
        },
        11,
    );
    let mut history: Vec<Observation> = Vec::new();
    for _ in 0..8 {
        let c = bo.next_config(&history, &[]);
        let a = c.get("a").unwrap().as_f64().unwrap();
        let b = c.get("b").unwrap().as_f64().unwrap();
        history.push(Observation {
            config: c,
            value: (a - 0.3f64).powi(2) + (b - 0.6f64).powi(2),
        });
    }
    let best = history.iter().map(|o| o.value).fold(f64::INFINITY, f64::min);
    assert!(best < 0.3, "HLO-backed BO should make progress: best = {best}");
    // and the artifacts were genuinely exercised
    assert!(rt.executions.load(std::sync::atomic::Ordering::Relaxed) > 0);
}

#[test]
fn mlp_artifacts_train_a_real_model() {
    let Some(rt) = runtime_or_skip() else { return };
    use amt::runtime::mlp::{MlpDataset, MlpTrainer};
    let data = MlpDataset::generate(&rt, 5);
    let mut trainer = MlpTrainer::new(Arc::clone(&rt), 32, 1).unwrap();
    let (loss0, acc0) = trainer.evaluate(&data).unwrap();
    let mut last_train = f64::INFINITY;
    for _ in 0..25 {
        last_train = trainer.train_epoch(&data, 0.1, 1e-4).unwrap();
    }
    let (loss1, acc1) = trainer.evaluate(&data).unwrap();
    assert!(loss1 < loss0, "val loss should drop: {loss0} -> {loss1}");
    assert!(acc1 > acc0.max(0.75), "val accuracy should rise: {acc0} -> {acc1}");
    assert!(last_train.is_finite());
    // unknown width is rejected cleanly
    assert!(MlpTrainer::new(Arc::clone(&rt), 999, 1).is_err());
}
