//! Property-based tests over the core invariants (DESIGN.md §7).
//!
//! The offline crate set has no proptest, so these are seeded randomized
//! properties driven by the crate's own deterministic RNG: hundreds of
//! random cases per invariant, fully reproducible, with the failing case's
//! seed printed on assertion failure.

use amt::earlystop::{CurveHistory, MedianRule, StoppingPolicy};
use amt::gp::{expected_improvement, kernel, Dataset, NativeBackend, SurrogateBackend, Theta};
use amt::linalg::{cho_solve, chol_append_row, cholesky, Matrix};
use amt::rng::Rng;
use amt::sobol::Sobol;
use amt::space::{
    categorical, continuous, integer, Config, Scaling, SearchSpace, Value,
};
use amt::store::MetadataStore;

fn random_space(rng: &mut Rng) -> SearchSpace {
    let n_params = 1 + rng.below(4);
    let mut params = Vec::new();
    for i in 0..n_params {
        match rng.below(3) {
            0 => {
                let min = rng.uniform_range(-10.0, 10.0);
                let max = min + rng.uniform_range(0.5, 20.0);
                let scaling = if min > 0.0 && rng.uniform() < 0.5 {
                    Scaling::Logarithmic
                } else {
                    Scaling::Linear
                };
                params.push(continuous(&format!("c{i}"), min, max, scaling));
            }
            1 => {
                let min = rng.int_range(-50, 50);
                let max = min + 1 + rng.below(100) as i64;
                params.push(integer(&format!("i{i}"), min, max, Scaling::Linear));
            }
            _ => {
                let k = 2 + rng.below(4);
                let cats: Vec<String> = (0..k).map(|j| format!("v{j}")).collect();
                let refs: Vec<&str> = cats.iter().map(String::as_str).collect();
                params.push(categorical(&format!("k{i}"), &refs));
            }
        }
    }
    SearchSpace::new(params).unwrap()
}

#[test]
fn prop_encode_decode_roundtrip() {
    // decode(encode(x)) == x for integer/categorical, ≈ for continuous
    for seed in 0..150u64 {
        let mut rng = Rng::new(seed);
        let space = random_space(&mut rng);
        let config = space.sample(&mut rng);
        let enc = space.encode(&config).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(enc.len(), space.encoded_dim(), "seed {seed}");
        for v in &enc {
            assert!((-1e-9..=1.0 + 1e-9).contains(v), "seed {seed}: encode out of cube");
        }
        let dec = space.decode(&enc);
        for p in &space.parameters {
            let a = config.get(p.name()).unwrap();
            let b = dec.get(p.name()).unwrap();
            match (a, b) {
                (Value::Int(x), Value::Int(y)) => assert_eq!(x, y, "seed {seed}"),
                (Value::Cat(x), Value::Cat(y)) => assert_eq!(x, y, "seed {seed}"),
                (Value::Float(x), Value::Float(y)) => {
                    assert!((x - y).abs() <= 1e-6 * (1.0 + x.abs()), "seed {seed}: {x} vs {y}")
                }
                _ => panic!("seed {seed}: type flip"),
            }
        }
    }
}

#[test]
fn prop_decode_total_on_arbitrary_unit_points() {
    // any point of [0,1]^D decodes to a valid, encodable configuration
    for seed in 200..300u64 {
        let mut rng = Rng::new(seed);
        let space = random_space(&mut rng);
        let u: Vec<f64> = (0..space.encoded_dim()).map(|_| rng.uniform()).collect();
        let config = space.decode(&u);
        assert!(space.encode(&config).is_ok(), "seed {seed}");
    }
}

#[test]
fn prop_sobol_in_bounds_and_distinct() {
    for seed in 0..20u64 {
        let dim = 1 + (seed as usize % amt::sobol::MAX_DIM);
        let mut sobol = Sobol::new(dim);
        let pts = sobol.take_points(128);
        for p in &pts {
            for &c in p {
                assert!((0.0..1.0).contains(&c), "dim {dim}");
            }
        }
        // successive points differ
        for w in pts.windows(2) {
            assert_ne!(w[0], w[1], "dim {dim}");
        }
    }
}

fn random_dataset(rng: &mut Rng, n: usize, d: usize) -> Dataset {
    Dataset::from_fn(n, d, |_, _| rng.uniform())
}

fn random_theta(rng: &mut Rng, d: usize) -> Theta {
    let mut theta = Theta::default_for_dim(d);
    for j in 0..d {
        theta.log_ls[j] = rng.uniform_range(-2.0, 1.0);
        theta.log_wa[j] = rng.uniform_range(-1.0, 1.0);
        theta.log_wb[j] = rng.uniform_range(-1.0, 1.0);
    }
    theta
}

#[test]
fn prop_gram_is_psd_and_symmetric() {
    for seed in 0..60u64 {
        let mut rng = Rng::new(seed);
        let n = 2 + rng.below(40);
        let d = 1 + rng.below(8);
        let x = random_dataset(&mut rng, n, d);
        let theta = random_theta(&mut rng, d);
        let k = kernel::gram(&x, &theta);
        for i in 0..n {
            for j in 0..n {
                assert!((k[(i, j)] - k[(j, i)]).abs() < 1e-12, "seed {seed}");
            }
        }
        assert!(cholesky(&k).is_ok(), "seed {seed}: gram not PD");
    }
}

#[test]
fn prop_blocked_scores_match_naive_reference() {
    // the blocked Kx·K⁻¹ scorer must reproduce the naive per-candidate
    // quadratic form to 1e-10 across random models and batches
    for seed in 0..25u64 {
        let mut rng = Rng::new(seed ^ 0x5C0);
        let n = 3 + rng.below(30);
        let d = 1 + rng.below(5);
        let x = random_dataset(&mut rng, n, d);
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let theta = random_theta(&mut rng, d);
        let Some(model) = amt::gp::GpModel::fit(&NativeBackend, &x, &y, vec![theta]) else {
            continue; // extreme thetas may be non-PD — rejected upstream too
        };
        let post = &model.posteriors[0];
        let m = 1 + rng.below(60);
        let cands = random_dataset(&mut rng, m, d);
        let fast = NativeBackend.posterior_scores(post, &cands, model.y_best_norm);
        // naive reference: mu = k·alpha, var = amp − kᵀ K⁻¹ k, per candidate
        let kx = kernel::cross(&cands, &post.x, &post.theta);
        let amp = post.theta.amp();
        for i in 0..m {
            let row = kx.row(i);
            let mu: f64 = row.iter().zip(&post.alpha).map(|(a, b)| a * b).sum();
            let mut quad = 0.0;
            for a in 0..n {
                let kinv_row = &post.k_inv.data[a * n..(a + 1) * n];
                let dot: f64 = kinv_row.iter().zip(row).map(|(u, v)| u * v).sum();
                quad += row[a] * dot;
            }
            let var = (amp - quad).max(1e-12);
            let ei = expected_improvement(mu, var, model.y_best_norm);
            assert!((fast[i].mu - mu).abs() < 1e-10, "seed {seed} mu[{i}]");
            assert!((fast[i].var - var).abs() < 1e-10, "seed {seed} var[{i}]");
            assert!((fast[i].ei - ei).abs() < 1e-10, "seed {seed} ei[{i}]");
        }
    }
}

#[test]
fn prop_rank1_cholesky_update_matches_full_refactorization() {
    // growing a GP training set one row at a time via chol_append_row must
    // track the full O(N³) factorization to 1e-10 at every step
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed ^ 0xA11);
        let d = 1 + rng.below(4);
        let theta = random_theta(&mut rng, d);
        let total = 4 + rng.below(25);
        let all = random_dataset(&mut rng, total, d);
        let start = 2 + rng.below(total - 3);
        let mut grown = all.slice(0..start);
        let mut l = match cholesky(&kernel::gram(&grown, &theta)) {
            Ok(l) => l,
            Err(_) => continue,
        };
        let k_diag = theta.amp() + theta.noise() + kernel::JITTER;
        for i in start..total {
            let row = all.row(i);
            let col = kernel::cross_row(row, &grown, &theta);
            l = chol_append_row(&l, &col, k_diag).unwrap_or_else(|p| {
                panic!("seed {seed}: append rejected at pivot {p}")
            });
            grown.push_row(row);
            let full = cholesky(&kernel::gram(&grown, &theta)).unwrap();
            let diff = full.max_abs_diff(&l);
            assert!(diff < 1e-10, "seed {seed} rows {}: max |Δ| = {diff}", grown.len());
        }
    }
}

#[test]
fn prop_parallel_and_sequential_scoring_bit_identical() {
    // order-stable reduction: the parallel scoring path must equal the
    // sequential one bit for bit, for any posterior-ensemble size
    for seed in 0..10u64 {
        let mut rng = Rng::new(seed ^ 0xFA12);
        let n = 70 + rng.below(40); // above the parallel-fit threshold
        let d = 1 + rng.below(4);
        let x = random_dataset(&mut rng, n, d);
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let thetas: Vec<Theta> = (0..1 + rng.below(8)).map(|_| random_theta(&mut rng, d)).collect();
        let Some(model) = amt::gp::GpModel::fit(&NativeBackend, &x, &y, thetas) else {
            continue;
        };
        let cands = random_dataset(&mut rng, 64 + rng.below(200), d);
        let par = model.score(&NativeBackend, &cands);
        let seq = model.score_sequential(&NativeBackend, &cands);
        for (i, (a, b)) in par.iter().zip(&seq).enumerate() {
            assert_eq!(a.ei.to_bits(), b.ei.to_bits(), "seed {seed} ei[{i}]");
            assert_eq!(a.mu.to_bits(), b.mu.to_bits(), "seed {seed} mu[{i}]");
            assert_eq!(a.var.to_bits(), b.var.to_bits(), "seed {seed} var[{i}]");
        }
    }
}

#[test]
fn prop_seeded_proposals_bit_identical_across_runs() {
    // full propose (parallel anchor scoring + local refinement) from the
    // same seed twice ⇒ identical proposals, bit for bit
    use amt::acquisition::{propose, AcquisitionConfig};
    for seed in 0..6u64 {
        let mut rng = Rng::new(seed ^ 0xB17);
        let d = 1 + rng.below(3);
        let x = random_dataset(&mut rng, 12 + rng.below(20), d);
        let y: Vec<f64> = x.rows().map(|p| p.iter().map(|v| (v - 0.4).powi(2)).sum()).collect();
        let Some(model) =
            amt::gp::GpModel::fit(&NativeBackend, &x, &y, vec![Theta::default_for_dim(d)])
        else {
            continue;
        };
        let cfg = AcquisitionConfig { num_anchors: 300, ..Default::default() };
        let mut r1 = Rng::new(900 + seed);
        let mut r2 = Rng::new(900 + seed);
        let a = propose(&model, &NativeBackend, d, &[], &cfg, &mut r1);
        let b = propose(&model, &NativeBackend, d, &[], &cfg, &mut r2);
        assert_eq!(a.x, b.x, "seed {seed}");
        assert_eq!(a.acq_value.to_bits(), b.acq_value.to_bits(), "seed {seed}");
    }
}

#[test]
fn prop_cholesky_solve_residual_small() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed ^ 0xC0);
        let n = 1 + rng.below(30);
        let mut a = Matrix::zeros(n, n);
        for v in a.data.iter_mut() {
            *v = rng.normal();
        }
        let mut spd = a.matmul(&a.transpose());
        for i in 0..n {
            spd[(i, i)] += n as f64;
        }
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let l = cholesky(&spd).unwrap();
        let x = cho_solve(&l, &b);
        let r = spd.matvec(&x);
        for (u, v) in r.iter().zip(&b) {
            assert!((u - v).abs() < 1e-7, "seed {seed}");
        }
    }
}

#[test]
fn prop_ei_nonnegative_and_monotone_in_sigma() {
    for seed in 0..100u64 {
        let mut rng = Rng::new(seed ^ 0xE1);
        let mu = rng.uniform_range(-3.0, 3.0);
        let y_best = rng.uniform_range(-3.0, 3.0);
        let v1 = rng.uniform_range(1e-6, 2.0);
        let v2 = v1 * rng.uniform_range(1.1, 4.0);
        let e1 = expected_improvement(mu, v1, y_best);
        let e2 = expected_improvement(mu, v2, y_best);
        assert!(e1 >= 0.0 && e2 >= 0.0, "seed {seed}");
        // more uncertainty ⇒ no less expected improvement (fixed mu)
        assert!(e2 >= e1 - 1e-12, "seed {seed}: {e2} < {e1}");
        // EI at least the certain improvement
        assert!(e1 >= (y_best - mu).max(0.0) - 1e-9, "seed {seed}");
    }
}

#[test]
fn prop_posterior_var_nonnegative_and_interpolation() {
    for seed in 0..25u64 {
        let mut rng = Rng::new(seed ^ 0xF2);
        let n = 3 + rng.below(20);
        let d = 1 + rng.below(4);
        let x = random_dataset(&mut rng, n, d);
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let model =
            amt::gp::GpModel::fit(&NativeBackend, &x, &y, vec![Theta::default_for_dim(d)])
                .unwrap();
        let scores = model.score(&NativeBackend, &x);
        for (i, s) in scores.iter().enumerate() {
            assert!(s.var >= 0.0, "seed {seed}");
            // training points have small posterior variance
            assert!(s.var < 0.2, "seed {seed} point {i}: var {}", s.var);
        }
    }
}

#[test]
fn prop_median_rule_monotone_in_value() {
    // if the rule stops a curve, it stops every strictly worse curve
    for seed in 0..50u64 {
        let mut rng = Rng::new(seed ^ 0xAB);
        let mut h = CurveHistory::default();
        for _ in 0..4 {
            let c: Vec<f64> = (0..10).map(|_| rng.uniform()).collect();
            h.push(c, true);
        }
        let rule = MedianRule::default();
        let epoch = 3 + rng.below(7) as u32;
        let base: Vec<f64> = (0..epoch as usize).map(|_| rng.uniform()).collect();
        let worse: Vec<f64> = base.iter().map(|v| v + 0.5).collect();
        if rule.should_stop(&base, epoch, &h) {
            assert!(rule.should_stop(&worse, epoch, &h), "seed {seed}");
        }
    }
}

#[test]
fn prop_store_versions_strictly_increase() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed ^ 0x57);
        let store = MetadataStore::new();
        let mut last = 0;
        for i in 0..50 {
            let v = store.put("t", "k", amt::json::Json::Num(i as f64));
            assert_eq!(v, last + 1, "seed {seed}");
            last = v;
            // interleaved conditional writes with a stale version must fail
            if rng.uniform() < 0.3 && last > 1 {
                assert!(store
                    .put_if("t", "k", amt::json::Json::Null, Some(last - 1))
                    .is_err());
            }
        }
    }
}

#[test]
fn prop_sharded_store_scans_match_single_lock_reference() {
    // the lock-striped store must be observationally identical to the old
    // single-lock store: same versions, same sorted scans/listings, and
    // scan_page pagination reassembles exactly the full scan
    use amt::json::Json;
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed ^ 0xA11CE);
        let sharded = MetadataStore::with_shards(2 + rng.below(14));
        let reference = MetadataStore::with_shards(1);
        let tables = ["tuning_jobs", "training_jobs", "misc"];
        for step in 0..300 {
            let table = tables[rng.below(tables.len())];
            let key = format!(
                "{}-{:03}",
                ["job", "train", "x"][rng.below(3)],
                rng.below(40)
            );
            match rng.below(5) {
                0..=1 => {
                    let v = Json::Num(step as f64);
                    assert_eq!(
                        sharded.put(table, &key, v.clone()),
                        reference.put(table, &key, v),
                        "seed {seed} step {step}"
                    );
                }
                2 => {
                    // both stores hold identical state, so conditioning on
                    // the reference's current version must behave the same
                    let expected = if rng.uniform() < 0.7 {
                        reference.get(table, &key).map(|(v, _)| v)
                    } else {
                        Some(rng.below(5) as u64 + 1) // often stale
                    };
                    let v = Json::Str(format!("s{step}"));
                    assert_eq!(
                        sharded.put_if(table, &key, v.clone(), expected),
                        reference.put_if(table, &key, v, expected),
                        "seed {seed} step {step}"
                    );
                }
                3 => {
                    assert_eq!(
                        sharded.delete(table, &key),
                        reference.delete(table, &key),
                        "seed {seed} step {step}"
                    );
                }
                _ => {
                    assert_eq!(
                        sharded.get(table, &key),
                        reference.get(table, &key),
                        "seed {seed} step {step}"
                    );
                }
            }
        }
        // full observational equality across prefixes and tables
        for table in tables {
            for prefix in ["", "job", "job-0", "train-01", "x-", "nope"] {
                assert_eq!(
                    sharded.list_keys(table, prefix),
                    reference.list_keys(table, prefix),
                    "seed {seed} table {table} prefix {prefix}"
                );
                assert_eq!(
                    sharded.scan(table, prefix),
                    reference.scan(table, prefix),
                    "seed {seed} table {table} prefix {prefix}"
                );
            }
            // pagination at a random page size reassembles the full scan
            let page_size = 1 + rng.below(9);
            let mut paged = Vec::new();
            let mut cursor: Option<String> = None;
            loop {
                let page = sharded.scan_page(table, "", cursor.as_deref(), page_size);
                if page.is_empty() {
                    break;
                }
                assert!(page.len() <= page_size, "seed {seed}");
                cursor = Some(page.last().unwrap().0.clone());
                paged.extend(page);
            }
            assert_eq!(paged, reference.scan(table, ""), "seed {seed} table {table}");
        }
        // snapshots are byte-identical, and restoring one preserves versions
        assert_eq!(sharded.snapshot(), reference.snapshot(), "seed {seed}");
        let restored = MetadataStore::restore(&sharded.snapshot()).unwrap();
        for table in tables {
            for key in reference.list_keys(table, "") {
                assert_eq!(
                    restored.get(table, &key),
                    reference.get(table, &key),
                    "seed {seed} table {table} key {key}"
                );
            }
        }
    }
}

#[test]
fn prop_parallelism_never_exceeded() {
    // from the evaluation records of real tuning runs: at no virtual time
    // do more than L evaluations overlap
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;
    for seed in 0..6u64 {
        let parallel = 1 + (seed % 4) as usize;
        let request = amt::config::TuningJobRequest {
            name: format!("prop-par-{seed}"),
            objective: "branin".into(),
            strategy: "random".into(),
            max_training_jobs: 12,
            max_parallel_jobs: parallel as u32,
            seed,
            ..Default::default()
        };
        let obj: Arc<dyn amt::objectives::Objective> =
            amt::objectives::by_name("branin").unwrap().into();
        let strat = amt::strategies::by_name(
            "random",
            &obj.space(),
            Arc::new(NativeBackend),
            seed,
        )
        .unwrap();
        let out = amt::coordinator::TuningJobRunner::new(
            request,
            obj,
            strat,
            amt::coordinator::stopping_by_name("off").unwrap(),
            amt::platform::TrainingPlatform::new(
                amt::platform::PlatformConfig::default(),
                seed,
            ),
            Arc::new(MetadataStore::new()),
            Arc::new(amt::metrics::MetricsService::new()),
            Arc::new(AtomicBool::new(false)),
        )
        .run();
        // sweep all interval endpoints
        let mut events: Vec<(f64, i32)> = Vec::new();
        for e in &out.evaluations {
            events.push((e.submitted_at, 1));
            events.push((e.ended_at, -1));
        }
        events.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut live = 0;
        for (_, delta) in events {
            live += delta;
            assert!(
                live <= parallel as i32,
                "seed {seed}: {live} concurrent evaluations > L={parallel}"
            );
        }
    }
}

#[test]
fn prop_warmstart_transfer_always_encodable() {
    use amt::strategies::Observation;
    use amt::warmstart::{transfer, ParentJob, TransferOptions};
    for seed in 0..60u64 {
        let mut rng = Rng::new(seed ^ 0x77);
        let parent_space = random_space(&mut rng);
        let child_space = random_space(&mut rng);
        let observations: Vec<Observation> = (0..10)
            .map(|_| Observation {
                config: parent_space.sample(&mut rng),
                value: rng.normal(),
            })
            .collect();
        let parent = ParentJob { name: "p".into(), space: parent_space, observations };
        let transferred = transfer(&[parent], &child_space, &TransferOptions::default());
        for obs in &transferred {
            assert!(
                child_space.encode(&obs.config).is_ok(),
                "seed {seed}: transferred config not encodable in child space"
            );
        }
    }
}

#[test]
fn prop_json_roundtrip_arbitrary_configs() {
    for seed in 0..80u64 {
        let mut rng = Rng::new(seed ^ 0x11);
        let space = random_space(&mut rng);
        let config = space.sample(&mut rng);
        let j = amt::space::config_to_json(&config);
        let text = j.to_string();
        let parsed = amt::json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let back: Config = amt::space::config_from_json(&parsed).unwrap();
        // numeric equality after the clamp-coercion step
        let coerced = space.clamp(&back);
        for p in &space.parameters {
            let a = config.get(p.name()).unwrap();
            let b = coerced.get(p.name()).unwrap();
            match (a, b) {
                (Value::Float(x), Value::Float(y)) => {
                    assert!((x - y).abs() < 1e-9, "seed {seed}")
                }
                (Value::Int(x), Value::Int(y)) => assert_eq!(x, y, "seed {seed}"),
                (Value::Cat(x), Value::Cat(y)) => assert_eq!(x, y, "seed {seed}"),
                _ => panic!("seed {seed}: type flip"),
            }
        }
    }
}

/// The batched mutation paths (`put_batch`, `emit_batch`, and the
/// `Wal::append_batch` they ride on) must be **bit-identical** to the
/// per-record paths: same returned versions, same store snapshot, same
/// metric series, and — single-threaded, with the same record order —
/// byte-identical WAL files. A recovery replay of the batch-built WAL
/// (which itself uses the batched `PutRaw` path) must then reproduce
/// the exact live state.
#[test]
fn prop_batched_mutations_bit_identical_to_per_record() {
    use amt::durability::recovery;
    use amt::durability::wal::{Wal, WAL_FILE};
    use amt::json::Json;
    use amt::metrics::MetricsService;
    use amt::store::{MetadataStore, StoreBatchOp};
    use std::sync::Arc;

    enum OpSpec {
        Put { table: &'static str, key: String, value: Json },
        Del { table: &'static str, key: String },
        Emit { stream: String, time: f64, value: f64 },
    }

    let base = std::env::temp_dir().join(format!(
        "amt-prop-batch-{}-{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));

    for seed in 0..12u64 {
        let mut rng = Rng::new(seed ^ 0xBA7C);
        // avoid "tuning_jobs": recovery scans it for resumable jobs
        let tables = ["training_jobs", "metrics_meta", "misc"];
        let mut specs: Vec<OpSpec> = Vec::new();
        for step in 0..220 {
            match rng.below(6) {
                0..=2 => specs.push(OpSpec::Put {
                    table: tables[rng.below(3)],
                    key: format!("k-{:02}", rng.below(30)),
                    value: if rng.uniform() < 0.5 {
                        Json::Num(step as f64 + rng.uniform())
                    } else {
                        Json::obj(vec![("s", Json::Str(format!("v{step}")))])
                    },
                }),
                3 => specs.push(OpSpec::Del {
                    table: tables[rng.below(3)],
                    key: format!("k-{:02}", rng.below(30)),
                }),
                _ => specs.push(OpSpec::Emit {
                    stream: format!("job-{}/loss", rng.below(6)),
                    time: step as f64,
                    value: rng.uniform(),
                }),
            }
        }

        let dir_ref = base.join(format!("ref-{seed}"));
        let dir_bat = base.join(format!("bat-{seed}"));
        let wal_ref = Arc::new(Wal::create(&dir_ref).unwrap());
        let wal_bat = Arc::new(Wal::create(&dir_bat).unwrap());
        let store_ref = MetadataStore::new();
        let store_bat = MetadataStore::new();
        let metrics_ref = MetricsService::new();
        let metrics_bat = MetricsService::new();
        store_ref.attach_wal(Arc::clone(&wal_ref));
        metrics_ref.attach_wal(Arc::clone(&wal_ref));
        store_bat.attach_wal(Arc::clone(&wal_bat));
        metrics_bat.attach_wal(Arc::clone(&wal_bat));

        // reference: one call per record, in order
        let mut versions_ref: Vec<u64> = Vec::new();
        for spec in &specs {
            match spec {
                OpSpec::Put { table, key, value } => {
                    versions_ref.push(store_ref.put(table, key, value.clone()))
                }
                OpSpec::Del { table, key } => {
                    store_ref.delete(table, key);
                }
                OpSpec::Emit { stream, time, value } => {
                    metrics_ref.emit(stream, *time, *value)
                }
            }
        }

        // batch side: maximal homogeneous runs (store ops vs emits),
        // randomly split further so batch sizes vary from 1 upward.
        // Run order preserves record order, so the WAL files must match
        // byte for byte.
        let mut split = Rng::new(seed ^ 0x5911);
        let mut versions_bat: Vec<u64> = Vec::new();
        let mut i = 0;
        while i < specs.len() {
            let store_kind = !matches!(specs[i], OpSpec::Emit { .. });
            let mut j = i;
            while j < specs.len()
                && store_kind != matches!(specs[j], OpSpec::Emit { .. })
                && (j == i || split.uniform() > 0.3)
            {
                j += 1;
            }
            if store_kind {
                let ops: Vec<StoreBatchOp<'_>> = specs[i..j]
                    .iter()
                    .map(|s| match s {
                        OpSpec::Put { table, key, value } => {
                            StoreBatchOp::Put { table, key, value }
                        }
                        OpSpec::Del { table, key } => StoreBatchOp::Delete { table, key },
                        OpSpec::Emit { .. } => unreachable!(),
                    })
                    .collect();
                let got = store_bat.put_batch(&ops);
                assert_eq!(got.len(), ops.len(), "seed {seed}");
                for (op, v) in specs[i..j].iter().zip(&got) {
                    match op {
                        OpSpec::Put { .. } => versions_bat.push(*v),
                        OpSpec::Del { .. } => assert_eq!(*v, 0, "seed {seed}"),
                        OpSpec::Emit { .. } => unreachable!(),
                    }
                }
            } else {
                let points: Vec<(&str, f64, f64)> = specs[i..j]
                    .iter()
                    .map(|s| match s {
                        OpSpec::Emit { stream, time, value } => {
                            (stream.as_str(), *time, *value)
                        }
                        _ => unreachable!(),
                    })
                    .collect();
                metrics_bat.emit_batch(&points);
            }
            i = j;
        }

        assert_eq!(versions_ref, versions_bat, "seed {seed}: versions diverged");
        assert_eq!(
            store_ref.snapshot(),
            store_bat.snapshot(),
            "seed {seed}: store state diverged"
        );
        assert_eq!(
            store_ref.write_count(),
            store_bat.write_count(),
            "seed {seed}"
        );
        let mut streams = metrics_ref.list_streams("");
        streams.extend(metrics_bat.list_streams(""));
        streams.sort();
        streams.dedup();
        for s in &streams {
            assert_eq!(
                metrics_ref.series(s),
                metrics_bat.series(s),
                "seed {seed}: series {s} diverged"
            );
        }

        wal_ref.commit().unwrap();
        wal_bat.commit().unwrap();
        let bytes_ref = std::fs::read(dir_ref.join(WAL_FILE)).unwrap();
        let bytes_bat = std::fs::read(dir_bat.join(WAL_FILE)).unwrap();
        assert_eq!(bytes_ref, bytes_bat, "seed {seed}: WAL files diverged");

        // recovery replays the batch-built WAL through the batched
        // PutRaw/emit paths and must land on the exact live state
        let recovered = recovery::open(&dir_bat).unwrap();
        assert!(recovered.replayed_records > 0, "seed {seed}");
        assert_eq!(
            recovered.store.snapshot(),
            store_bat.snapshot(),
            "seed {seed}: recovered store diverged"
        );
        for s in &streams {
            assert_eq!(
                recovered.metrics.series(s),
                metrics_bat.series(s),
                "seed {seed}: recovered series {s} diverged"
            );
        }
    }
    let _ = std::fs::remove_dir_all(&base);
}
