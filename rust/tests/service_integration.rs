//! Whole-service integration tests: API → workflow → platform → store →
//! metrics, across strategies, early stopping, warm start and failure
//! injection — the §3 architecture exercised end to end (native backend;
//! the artifact path is covered by `hlo_integration.rs`).

use std::sync::Arc;

use amt::api::{AmtService, ApiError};
use amt::config::TuningJobRequest;
use amt::platform::PlatformConfig;

fn request(name: &str) -> TuningJobRequest {
    TuningJobRequest {
        name: name.into(),
        objective: "branin".into(),
        strategy: "random".into(),
        max_training_jobs: 6,
        max_parallel_jobs: 2,
        ..Default::default()
    }
}

#[test]
fn all_strategies_complete_through_the_service() {
    let svc = AmtService::new(PlatformConfig::noiseless());
    for strategy in ["random", "sobol", "grid", "bayesian"] {
        let mut r = request(&format!("strat-{strategy}"));
        r.strategy = strategy.into();
        r.max_training_jobs = 5;
        let name = svc.create_tuning_job(r).unwrap();
        let out = svc.wait(&name).unwrap();
        assert_eq!(out.evaluations.len(), 5, "{strategy}");
        assert!(out.best.is_some(), "{strategy}");
    }
}

#[test]
fn all_stopping_policies_complete() {
    let svc = AmtService::new(PlatformConfig::noiseless());
    for early in ["off", "median", "linear", "asha"] {
        let mut r = request(&format!("es-{early}"));
        r.objective = "gdelt_single".into();
        r.early_stopping = early.into();
        r.max_training_jobs = 10;
        let name = svc.create_tuning_job(r).unwrap();
        let out = svc.wait(&name).unwrap();
        assert_eq!(out.evaluations.len(), 10, "{early}");
    }
}

#[test]
fn failure_storm_is_absorbed() {
    // §3.3: the workflow must stay robust under heavy failure injection
    let svc = AmtService::new(PlatformConfig {
        provisioning_failure_rate: 0.25,
        training_failure_rate: 0.20,
        ..Default::default()
    });
    let mut r = request("storm");
    r.max_training_jobs = 20;
    r.max_retries_per_job = 3;
    let name = svc.create_tuning_job(r).unwrap();
    let out = svc.wait(&name).unwrap();
    assert_eq!(out.evaluations.len(), 20);
    assert!(out.retries > 0);
    let completed = out
        .evaluations
        .iter()
        .filter(|e| e.status == amt::platform::TrainingJobStatus::Completed)
        .count();
    assert!(completed >= 12, "only {completed}/20 survived the storm");
    // best is still found despite failures
    assert!(out.best.is_some());
}

#[test]
fn chained_warm_start_improves_over_generations() {
    // three generations on the same maximization workload; each warm starts
    // from all previous ones (the §6.4 pattern)
    let svc = AmtService::new(PlatformConfig::noiseless());
    let mut parents: Vec<String> = Vec::new();
    let mut bests = Vec::new();
    for generation in 0..3 {
        let r = TuningJobRequest {
            name: format!("gen-{generation}"),
            objective: "caltech_base".into(),
            strategy: "bayesian".into(),
            max_training_jobs: 8,
            max_parallel_jobs: 1,
            warm_start_parents: parents.clone(),
            seed: generation as u64,
            ..Default::default()
        };
        let name = svc.create_tuning_job(r).unwrap();
        let out = svc.wait(&name).unwrap();
        bests.push(out.best.map(|b| b.1).unwrap_or(0.0));
        parents.push(name);
    }
    // maximization: later generations should not regress materially
    assert!(
        bests[2] >= bests[0] - 0.02,
        "warm start regressed: {bests:?}"
    );
}

#[test]
fn store_state_consistent_with_outcomes() {
    let svc = AmtService::new(PlatformConfig::noiseless());
    let name = svc.create_tuning_job(request("consistent")).unwrap();
    let out = svc.wait(&name).unwrap();
    let store = svc.store();
    // every evaluation has a persisted record with terminal status
    for e in &out.evaluations {
        let (_, rec) = store
            .get("training_jobs", &e.training_job_name)
            .unwrap_or_else(|| panic!("missing record for {}", e.training_job_name));
        let status = rec.get("status").and_then(amt::json::Json::as_str).unwrap();
        assert!(["Completed", "Stopped", "Failed"].contains(&status), "{status}");
    }
    // snapshot → restore → same records
    let snapshot = store.snapshot();
    let restored = amt::store::MetadataStore::restore(&snapshot).unwrap();
    assert_eq!(
        restored.list_keys("training_jobs", "consistent-"),
        store.list_keys("training_jobs", "consistent-")
    );
}

#[test]
fn describe_is_callable_while_running() {
    let svc = AmtService::new(PlatformConfig::noiseless());
    let mut r = request("live");
    r.max_training_jobs = 50;
    let name = svc.create_tuning_job(r).unwrap();
    // poll Describe concurrently with the workflow thread
    for _ in 0..20 {
        let d = svc.describe_tuning_job(&name).unwrap();
        assert!(["InProgress", "Completed"].contains(&d.status.as_str()));
    }
    svc.stop_tuning_job(&name).unwrap();
    svc.wait(&name).unwrap();
}

#[test]
fn metrics_streams_cover_all_epochs() {
    let svc = AmtService::new(PlatformConfig::noiseless());
    let name = svc.create_tuning_job(request("metrics")).unwrap();
    let out = svc.wait(&name).unwrap();
    let metrics = svc.metrics();
    for e in &out.evaluations {
        let series = metrics.series(&format!("{}/objective", e.training_job_name));
        assert_eq!(series.len(), e.curve.len(), "{}", e.training_job_name);
        // values match the recorded curve in order
        for (p, v) in series.iter().zip(&e.curve) {
            assert_eq!(p.value, *v);
        }
    }
}

#[test]
fn distributed_instance_count_shortens_jobs() {
    let run = |instances: u32| {
        let svc = AmtService::new(PlatformConfig::noiseless());
        let mut r = request(&format!("dist-{instances}"));
        r.objective = "gdelt_distributed".into();
        r.instance_count = instances;
        r.max_training_jobs = 4;
        r.max_parallel_jobs = 1;
        let name = svc.create_tuning_job(r).unwrap();
        svc.wait(&name).unwrap().total_seconds
    };
    assert!(run(8) < run(1) * 0.6);
}

#[test]
fn stopped_parent_is_still_a_valid_warm_start_source() {
    let svc = AmtService::new(PlatformConfig::noiseless());
    let mut r = request("stopped-parent");
    r.max_training_jobs = 400;
    let name = svc.create_tuning_job(r).unwrap();
    // let some evaluations land, then stop
    loop {
        if svc.describe_tuning_job(&name).map(|d| d.evaluations >= 3).unwrap_or(false) {
            break;
        }
        std::thread::yield_now();
    }
    svc.stop_tuning_job(&name).unwrap();
    svc.wait(&name).unwrap();

    let mut child = request("child-of-stopped");
    child.strategy = "bayesian".into();
    child.warm_start_parents = vec![name];
    let cname = svc.create_tuning_job(child).unwrap();
    assert_eq!(svc.wait(&cname).unwrap().evaluations.len(), 6);
}

#[test]
fn error_paths_do_not_poison_the_service() {
    let svc = AmtService::new(PlatformConfig::noiseless());
    let _ = svc.describe_tuning_job("nope");
    let _ = svc.stop_tuning_job("nope");
    let mut bad = request("bad");
    bad.max_parallel_jobs = 0;
    assert!(matches!(svc.create_tuning_job(bad), Err(ApiError::Validation(_))));
    // a healthy job still runs fine afterwards
    let name = svc.create_tuning_job(request("healthy")).unwrap();
    assert_eq!(svc.wait(&name).unwrap().evaluations.len(), 6);
    assert!(svc.availability() < 1.0);
    assert!(svc.availability() > 0.2); // 3 deliberate errors out of 4 calls
}

#[test]
fn custom_objective_through_public_api() {
    // a user-supplied workload (the "custom algorithm" path)
    struct Parabola;
    impl amt::objectives::Objective for Parabola {
        fn name(&self) -> &str {
            "parabola"
        }
        fn space(&self) -> amt::space::SearchSpace {
            amt::space::SearchSpace::new(vec![amt::space::continuous(
                "x",
                -1.0,
                1.0,
                amt::space::Scaling::Linear,
            )])
            .unwrap()
        }
        fn max_epochs(&self) -> u32 {
            3
        }
        fn curve(&self, config: &amt::space::Config, _seed: u64) -> Vec<f64> {
            let x = config.get("x").unwrap().as_f64().unwrap();
            vec![x * x + 1.0, x * x + 0.5, x * x]
        }
    }
    let svc = AmtService::new(PlatformConfig::noiseless());
    let mut r = request("custom");
    r.objective = "parabola".into();
    r.strategy = "bayesian".into();
    r.max_training_jobs = 10;
    let name = svc.create_custom_tuning_job(r, Arc::new(Parabola)).unwrap();
    let out = svc.wait(&name).unwrap();
    let (cfg, best) = out.best.unwrap();
    assert!(best < 0.25, "BO should approach x=0: best {best} at {cfg:?}");
}
