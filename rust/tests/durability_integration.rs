//! Durability-engine integration tests (DESIGN.md §10): WAL + per-shard
//! snapshots + crash recovery through the public `AmtService` surface.
//!
//! The centerpiece is the kill/recover bit-identity property: a tuning
//! job interrupted at *any* WAL record boundary and recovered via
//! `TuningService::open` must finish with exactly the best-config
//! trajectory, evaluation records and final store contents (values *and*
//! versions) of an uninterrupted run. Every job is a pure function of
//! its request seed on its own discrete-event timeline, so recovery's
//! reset-and-replay resume is exact — these tests pin that end to end,
//! including torn-write tails and the point-in-time guarantee of the
//! per-shard snapshot capture.

use std::path::PathBuf;
use std::sync::Arc;

use amt::api::{AmtService, TuningService};
use amt::config::TuningJobRequest;
use amt::durability::snapshot;
use amt::durability::wal::{Wal, WalRecord, WAL_FILE};
use amt::gp::NativeBackend;
use amt::metrics::MetricsService;
use amt::platform::PlatformConfig;
use amt::scheduler::SchedulerConfig;
use amt::store::MetadataStore;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "amt-dur-it-{tag}-{}-{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn open_svc(dir: &PathBuf) -> AmtService {
    // small batch slices force plenty of Pending boundaries (checkpoints)
    AmtService::open_with_options(
        dir,
        PlatformConfig::noiseless(),
        Arc::new(NativeBackend),
        SchedulerConfig { workers: 2, batch_steps: 8 },
    )
    .unwrap()
}

fn job_request(name: &str) -> TuningJobRequest {
    TuningJobRequest {
        name: name.into(),
        objective: "branin".into(),
        strategy: "random".into(),
        max_training_jobs: 5,
        max_parallel_jobs: 2,
        seed: 11,
        ..Default::default()
    }
}

/// Everything the identity comparison looks at.
struct RunFingerprint {
    store_snapshot: String,
    trajectory: Vec<(u64, u64)>,
    evaluations: Vec<(String, Option<u64>, u64)>,
    eval_series: Vec<(u64, u64)>,
    epoch_series: Vec<(u64, u64)>,
}

fn fingerprint(svc: &AmtService, outcome: Option<&amt::coordinator::TuningJobOutcome>, name: &str) -> RunFingerprint {
    let series_bits = |stream: &str| -> Vec<(u64, u64)> {
        svc.metrics()
            .series(stream)
            .iter()
            .map(|p| (p.time.to_bits(), p.value.to_bits()))
            .collect()
    };
    RunFingerprint {
        store_snapshot: svc.store().snapshot(),
        trajectory: outcome
            .map(|o| {
                o.best_over_time(true)
                    .iter()
                    .map(|(t, v)| (t.to_bits(), v.to_bits()))
                    .collect()
            })
            .unwrap_or_default(),
        evaluations: outcome
            .map(|o| {
                o.evaluations
                    .iter()
                    .map(|e| {
                        (
                            e.training_job_name.clone(),
                            e.final_value.map(f64::to_bits),
                            e.ended_at.to_bits(),
                        )
                    })
                    .collect()
            })
            .unwrap_or_default(),
        eval_series: series_bits(&format!("{name}/evaluations")),
        epoch_series: series_bits(&format!("{name}-train-0000/objective")),
    }
}

/// Run the reference job durably to completion; return its fingerprint
/// and the complete WAL bytes + record boundaries.
fn reference_run(name: &str) -> (RunFingerprint, Vec<u8>, Vec<u64>) {
    let dir = tmpdir("ref");
    let svc = open_svc(&dir);
    svc.create_tuning_job(job_request(name)).unwrap();
    let outcome = svc.wait(name).unwrap();
    // the worker committed before publishing the outcome; this drains
    // anything later (there is nothing) and is a no-op otherwise
    svc.wal().unwrap().commit().unwrap();
    let fp = fingerprint(&svc, Some(&outcome), name);
    drop(svc); // crash-style teardown: no close(), no snapshot

    let wal_path = dir.join(WAL_FILE);
    let bytes = std::fs::read(&wal_path).unwrap();
    let scan = Wal::scan(&wal_path).unwrap();
    assert!(!scan.dropped_tail, "reference WAL must be clean");
    assert!(scan.records.len() > 10, "expected a substantial WAL");
    let _ = std::fs::remove_dir_all(&dir);
    (fp, bytes, scan.frame_ends)
}

fn assert_identical(a: &RunFingerprint, b: &RunFingerprint, what: &str) {
    assert_eq!(a.store_snapshot, b.store_snapshot, "{what}: store contents diverged");
    assert_eq!(a.eval_series, b.eval_series, "{what}: evaluations series diverged");
    assert_eq!(a.epoch_series, b.epoch_series, "{what}: epoch series diverged");
    // outcome-derived fields exist only when the recovered run was
    // (re)driven to completion in-process; a fully-terminal recovery
    // (cut == whole log) compares store + metrics only
    if !b.trajectory.is_empty() || !b.evaluations.is_empty() {
        assert_eq!(a.trajectory, b.trajectory, "{what}: best-config trajectory diverged");
        assert_eq!(a.evaluations, b.evaluations, "{what}: evaluation records diverged");
    }
}

/// Recover from a WAL prefix (with optional garbage tail), finish the
/// job (resuming, or re-creating it if the prefix predates its creation)
/// and fingerprint the result.
fn recover_and_finish(name: &str, wal_bytes: &[u8], what: &str) -> RunFingerprint {
    let dir = tmpdir("cut");
    std::fs::write(dir.join(WAL_FILE), wal_bytes).unwrap();
    let svc = open_svc(&dir);
    let outcome = if svc.recovered_jobs().contains(&name.to_string()) {
        Some(svc.wait(name).unwrap())
    } else {
        match svc.describe_tuning_job(name) {
            Ok(d) => {
                // the prefix already contained the terminal record: the
                // job is recovered as finished, nothing to resume
                assert_eq!(d.status, "Completed", "{what}: unexpected status");
                None
            }
            Err(_) => {
                // prefix predates the job entirely: a fresh create must
                // still reproduce the reference run
                svc.create_tuning_job(job_request(name)).unwrap();
                Some(svc.wait(name).unwrap())
            }
        }
    };
    let fp = fingerprint(&svc, outcome.as_ref(), name);
    drop(svc);
    let _ = std::fs::remove_dir_all(&dir);
    fp
}

/// Acceptance property: kill at any WAL record boundary ⇒ recovery
/// finishes bit-identically to the uninterrupted run.
#[test]
fn kill_at_wal_record_boundaries_recovers_bit_identical() {
    let name = "dur-prop";
    let (reference, bytes, frame_ends) = reference_run(name);
    let n = frame_ends.len();

    // deterministic spread of cut points across the whole log, plus the
    // ends: 0 records (pre-create), n-1 (mid-finalize) and n (complete)
    let mut cuts: Vec<usize> = (0..8).map(|i| i * n / 8).collect();
    cuts.extend_from_slice(&[1, n - 1, n]);
    cuts.sort_unstable();
    cuts.dedup();

    for k in cuts {
        let len = if k == 0 { 0 } else { frame_ends[k - 1] as usize };
        let what = format!("cut after record {k}/{n}");
        let recovered = recover_and_finish(name, &bytes[..len], &what);
        if k < n {
            assert!(!recovered.trajectory.is_empty(), "{what}: no trajectory");
        }
        assert_identical(&reference, &recovered, &what);
    }
}

/// Satellite: a torn write (crash mid-record) is truncated by recovery —
/// never an error — and the job still recovers bit-identically.
#[test]
fn torn_write_mid_record_drops_tail_and_recovers() {
    let name = "dur-torn";
    let (reference, bytes, frame_ends) = reference_run(name);
    let n = frame_ends.len();
    for k in [n / 3, 2 * n / 3] {
        let boundary = frame_ends[k - 1] as usize;
        // keep a few bytes of the next frame: a torn group commit
        let torn_end = (boundary + 5).min(bytes.len());
        let what = format!("torn write inside record {}", k + 1);
        let recovered = recover_and_finish(name, &bytes[..torn_end], &what);
        assert_identical(&reference, &recovered, &what);
    }
}

/// The WAL carries per-Pending checkpoints that are v1 resume
/// snapshots; their execution cursors parse back for progress
/// reporting, and the full payload parses as a `ResumeSnapshot`.
#[test]
fn wal_checkpoints_carry_v1_resume_snapshots_with_parseable_cursors() {
    use amt::coordinator::{checkpoint_cursor, ResumeSnapshot};
    let name = "dur-ckpt";
    let (_, bytes, _) = reference_run(name);
    let dir = tmpdir("ckpt");
    std::fs::write(dir.join(WAL_FILE), &bytes).unwrap();
    let scan = Wal::scan(&dir.join(WAL_FILE)).unwrap();
    let mut checkpoints = 0;
    let mut last_clock = -1.0f64;
    for (_, rec) in &scan.records {
        if let WalRecord::Checkpoint { job, exec } = rec {
            assert_eq!(job, name);
            assert!(
                ResumeSnapshot::from_json(exec).is_some(),
                "checkpoints must carry v1 resume snapshots"
            );
            let state = checkpoint_cursor(exec).expect("cursor parses");
            assert!(state.clock >= last_clock, "checkpoint clocks must not regress");
            last_clock = state.clock;
            checkpoints += 1;
        }
    }
    assert!(checkpoints > 0, "batch_steps=8 must produce Pending checkpoints");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Durable lifecycle: close() writes per-shard snapshots + manifest;
/// reopen restores everything with an empty replay and no resumption.
#[test]
fn close_writes_shard_snapshots_and_reopen_restores() {
    let dir = tmpdir("lifecycle");
    let svc = open_svc(&dir);
    svc.create_tuning_job(job_request("dur-life")).unwrap();
    svc.wait("dur-life").unwrap();
    let snap_before = svc.store().snapshot();
    svc.close().unwrap();

    assert!(dir.join("MANIFEST.json").exists(), "manifest missing after close");
    assert!(dir.join("store-00.json").exists(), "per-shard files missing after close");
    assert!(dir.join("metrics-00.json").exists(), "metrics shard files missing");

    let svc: TuningService = open_svc(&dir);
    assert!(svc.recovered_jobs().is_empty(), "terminal jobs must not resume");
    assert_eq!(svc.store().snapshot(), snap_before);
    let d = svc.describe_tuning_job("dur-life").unwrap();
    assert_eq!(d.status, "Completed");
    assert_eq!(d.evaluations, 5);
    assert!(!svc.metrics().series("dur-life/evaluations").is_empty());

    // the reopened service keeps working durably: a second job runs and
    // survives another reopen alongside the first
    svc.create_tuning_job(job_request("dur-life-2")).unwrap();
    svc.wait("dur-life-2").unwrap();
    svc.close().unwrap();
    let svc = open_svc(&dir);
    assert_eq!(svc.list_tuning_jobs("dur-life").len(), 2);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite (WAL compaction): with `auto_checkpoint_bytes` set, a
/// long-running service's log stays bounded — every size-triggered
/// checkpoint snapshots and truncates the covered prefix — and the
/// final state matches an in-memory run of the same jobs exactly.
#[test]
fn auto_checkpoint_keeps_wal_bounded_and_state_exact() {
    let dir = tmpdir("autockpt");
    // v1 checkpoints are O(job state), not O(1) cursors (DESIGN.md §12
    // cost note), so a single slice's commit can carry several KB; the
    // threshold leaves room for that while still proving boundedness
    let limit = 64 * 1024u64;
    let requests: Vec<TuningJobRequest> = (0..8u64)
        .map(|i| {
            let mut r = job_request(&format!("dur-auto-{i}"));
            r.seed = 11 + i;
            r
        })
        .collect();

    let reference = AmtService::new(PlatformConfig::noiseless());
    let svc = AmtService::open_with_durability(
        &dir,
        PlatformConfig::noiseless(),
        Arc::new(NativeBackend),
        SchedulerConfig { workers: 2, batch_steps: 8 },
        amt::durability::DurabilityOptions {
            auto_checkpoint_bytes: Some(limit),
            ..Default::default()
        },
    )
    .unwrap();
    for r in &requests {
        reference.create_tuning_job(r.clone()).unwrap();
        svc.create_tuning_job(r.clone()).unwrap();
    }
    for r in &requests {
        reference.wait(&r.name).unwrap();
        svc.wait(&r.name).unwrap();
    }
    // 8 jobs append far more than the threshold, so the auto checkpoint
    // must have fired: a manifest exists and the log stayed bounded
    // (at most one over-limit commit before each compaction)
    assert!(dir.join("MANIFEST.json").exists(), "auto checkpoint never fired");
    let wal_len = std::fs::metadata(dir.join(WAL_FILE)).unwrap().len();
    assert!(
        wal_len < 2 * limit,
        "WAL grew unbounded despite auto checkpoints: {wal_len} bytes"
    );
    assert_eq!(
        svc.store().snapshot(),
        reference.store().snapshot(),
        "durable store diverged from the in-memory reference"
    );
    let snap_before = svc.store().snapshot();
    drop(svc); // crash-style teardown

    // recovery over snapshot + compacted tail restores the exact state
    let svc = open_svc(&dir);
    assert!(svc.recovered_jobs().is_empty());
    assert_eq!(svc.store().snapshot(), snap_before);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite (WAL compaction): a manual mid-flight `checkpoint()`
/// compacts the log while a job is still running; crash + recovery
/// afterwards is still bit-identical to an uninterrupted run.
#[test]
fn recovery_after_midflight_compaction_is_bit_identical() {
    let name = "dur-midcompact";
    let dir = tmpdir("midcompact");

    // uninterrupted reference (in-memory)
    let reference = AmtService::new(PlatformConfig::noiseless());
    reference.create_tuning_job(job_request(name)).unwrap();
    let ref_outcome = reference.wait(name).unwrap();
    let ref_fp = fingerprint(&reference, Some(&ref_outcome), name);

    {
        let svc = open_svc(&dir);
        // a quick sibling job supplies WAL traffic that a checkpoint
        // will cover...
        svc.create_tuning_job(job_request("dur-midcompact-pre")).unwrap();
        svc.wait("dur-midcompact-pre").unwrap();
        // ...then the job under test starts and the service checkpoints
        // (snapshot + compaction) while it is still in flight
        svc.create_tuning_job(job_request(name)).unwrap();
        svc.checkpoint().unwrap();
        // crash without waiting: the job stays InProgress on disk
        drop(svc);
    }

    let svc = open_svc(&dir);
    let fp = if svc.recovered_jobs().contains(&name.to_string()) {
        let outcome = svc.wait(name).unwrap();
        fingerprint(&svc, Some(&outcome), name)
    } else {
        // the scheduler may have finished the whole job before the
        // crash; store + metrics comparison still applies
        assert_eq!(svc.describe_tuning_job(name).unwrap().status, "Completed");
        fingerprint(&svc, None, name)
    };
    assert_eq!(
        ref_fp.eval_series, fp.eval_series,
        "evaluation series diverged after compaction + recovery"
    );
    assert_eq!(
        ref_fp.epoch_series, fp.epoch_series,
        "epoch series diverged after compaction + recovery"
    );
    if !fp.trajectory.is_empty() {
        assert_eq!(ref_fp.trajectory, fp.trajectory, "trajectory diverged");
        assert_eq!(ref_fp.evaluations, fp.evaluations, "evaluations diverged");
    }
    // the job-under-test's records match the reference run exactly
    // (values and versions); the sibling job precludes whole-store
    // equality, so compare the job's own records
    let job_records = |svc: &AmtService| -> Vec<(String, u64, String)> {
        let store = svc.store();
        let mut out = Vec::new();
        for key in store.list_keys("training_jobs", &format!("{name}-train-")) {
            let (ver, val) = store.get("training_jobs", &key).unwrap();
            out.push((key, ver, val.to_string()));
        }
        let (ver, val) = store.get("tuning_jobs", name).unwrap();
        out.push((name.to_string(), ver, val.to_string()));
        out
    };
    assert_eq!(job_records(&reference), job_records(&svc));
    let _ = std::fs::remove_dir_all(&dir);
}

/// Legacy single-blob snapshots (old `MetadataStore::snapshot()` dumps)
/// are still accepted by recovery when no manifest exists.
#[test]
fn legacy_single_blob_snapshot_still_restores() {
    let store = MetadataStore::new();
    store.put("tuning_jobs", "old-job", amt::json::parse(
        r#"{"status": "Completed", "request": {"name": "old-job"}}"#,
    ).unwrap());
    let dir = tmpdir("legacy");
    std::fs::write(dir.join("snapshot.json"), store.snapshot()).unwrap();

    let svc = AmtService::open(&dir, PlatformConfig::noiseless()).unwrap();
    assert!(svc.recovered_jobs().is_empty());
    let d = svc.describe_tuning_job("old-job").unwrap();
    assert_eq!(d.status, "Completed");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite regression: the per-shard snapshot capture is point-in-time.
/// A writer bumps `alpha` then `beta`; a capture that did not hold every
/// shard guard simultaneously could persist `beta > alpha` or
/// `alpha - beta > 1` — states that never existed.
#[test]
fn per_shard_snapshot_capture_is_point_in_time() {
    use std::sync::atomic::{AtomicBool, Ordering};
    let wal_dir = tmpdir("skew-wal");
    let snap_dir = tmpdir("skew-snap");
    let store = Arc::new(MetadataStore::new());
    let metrics = MetricsService::new();
    let wal = Wal::create(&wal_dir).unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    let writer = {
        let store = Arc::clone(&store);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut i = 0u64;
            while !stop.load(Ordering::Relaxed) {
                i += 1;
                store.put("inv", "alpha", amt::json::Json::Num(i as f64));
                store.put("inv", "beta", amt::json::Json::Num(i as f64));
            }
        })
    };
    for _ in 0..60 {
        snapshot::write_snapshot(&snap_dir, &store, &metrics, &wal).unwrap();
        let restored = MetadataStore::new();
        let rmetrics = MetricsService::new();
        snapshot::load_snapshot(&snap_dir, &restored, &rmetrics).unwrap().unwrap();
        let val = |k: &str| {
            restored.get("inv", k).map(|(_, v)| v.as_f64().unwrap()).unwrap_or(0.0)
        };
        let (a, b) = (val("alpha"), val("beta"));
        assert!(a >= b, "snapshot saw beta={b} ahead of alpha={a}");
        assert!(a - b <= 1.0, "snapshot skew: alpha={a} beta={b}");
    }
    stop.store(true, Ordering::Relaxed);
    writer.join().unwrap();
    let _ = std::fs::remove_dir_all(&wal_dir);
    let _ = std::fs::remove_dir_all(&snap_dir);
}

/// Warm-start children resume from the transfer observations persisted
/// at create time (the `warm_start` table), so recovery does not
/// re-resolve against a parent that may itself still be mid-replay —
/// the recovered child reproduces the uninterrupted run bit-exactly.
#[test]
fn warm_start_child_resumes_from_persisted_transfer() {
    let dir = tmpdir("ws-ref");
    let svc = open_svc(&dir);
    let mut parent = job_request("ws-parent");
    parent.max_training_jobs = 4;
    svc.create_tuning_job(parent).unwrap();
    svc.wait("ws-parent").unwrap();
    let mut child = job_request("ws-child");
    child.strategy = "bayesian".into();
    child.max_training_jobs = 3;
    child.warm_start_parents = vec!["ws-parent".into()];
    svc.create_tuning_job(child).unwrap();
    let out_ref = svc.wait("ws-child").unwrap();
    svc.wal().unwrap().commit().unwrap();
    assert!(
        svc.store().get("warm_start", "ws-child").is_some(),
        "transfer observations must be persisted at create"
    );
    let snap_ref = svc.store().snapshot();
    let traj_ref: Vec<(u64, u64)> = out_ref
        .best_over_time(true)
        .iter()
        .map(|(t, v)| (t.to_bits(), v.to_bits()))
        .collect();
    drop(svc);
    let bytes = std::fs::read(dir.join(WAL_FILE)).unwrap();
    let scan = Wal::scan(&dir.join(WAL_FILE)).unwrap();
    let n = scan.records.len();

    // the child's create-layer job record (its warm_start record was
    // written just before it, so any cut from here on has both)
    let child_create = scan
        .records
        .iter()
        .position(|(_, r)| {
            matches!(r, WalRecord::Put { table, key, .. }
                if table == "tuning_jobs" && key == "ws-child")
        })
        .expect("child create record in WAL");

    for cut in [child_create + 3, n - 2] {
        let len = scan.frame_ends[cut - 1] as usize;
        let dirk = tmpdir("ws-cut");
        std::fs::write(dirk.join(WAL_FILE), &bytes[..len]).unwrap();
        let svc = open_svc(&dirk);
        assert!(
            svc.recovered_jobs().contains(&"ws-child".to_string()),
            "cut {cut}: child must resume"
        );
        let out = svc.wait("ws-child").unwrap();
        let traj: Vec<(u64, u64)> = out
            .best_over_time(true)
            .iter()
            .map(|(t, v)| (t.to_bits(), v.to_bits()))
            .collect();
        assert_eq!(traj, traj_ref, "cut {cut}: warm-start child trajectory diverged");
        assert_eq!(svc.store().snapshot(), snap_ref, "cut {cut}: store diverged");
        drop(svc);
        let _ = std::fs::remove_dir_all(&dirk);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Fair-share satellite rides the durability PR: tenant weights flow
/// through the public API (create accepts them, validation bounds them).
#[test]
fn tenant_weight_accepted_and_validated_through_api() {
    let svc = AmtService::new(PlatformConfig::noiseless());
    let mut r = job_request("weighted");
    r.tenant_weight = 4;
    svc.create_tuning_job(r).unwrap();
    svc.wait("weighted").unwrap();

    let mut bad = job_request("zero-weight");
    bad.tenant_weight = 0;
    assert!(matches!(
        svc.create_tuning_job(bad),
        Err(amt::api::ApiError::Validation(_))
    ));
}
