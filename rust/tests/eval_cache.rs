//! Cross-job evaluation-cache integration tests (DESIGN.md §17): a
//! warm-start family dedupes training through the shared `eval_cache`
//! table, hits replay bit-identical outcomes, the cache rides the
//! durable plane across close/reopen, and both execution planes agree.

use std::collections::BTreeMap;

use amt::api::AmtService;
use amt::config::TuningJobRequest;
use amt::coordinator::TuningJobOutcome;
use amt::distributed::worker::spawn_loopback_worker;
use amt::platform::PlatformConfig;

/// Grid search is a pure cursor over the 16-point branin grid (k=4 per
/// axis), so every job with the same budget proposes the same configs —
/// overlap between family members is guaranteed, not probabilistic.
fn grid_request(name: &str, jobs: u32, parents: Vec<String>) -> TuningJobRequest {
    TuningJobRequest {
        name: name.into(),
        objective: "branin".into(),
        strategy: "grid".into(),
        max_training_jobs: jobs,
        max_parallel_jobs: 2,
        seed: 5,
        eval_cache: true,
        warm_start_parents: parents,
        ..Default::default()
    }
}

fn run(svc: &AmtService, r: TuningJobRequest) -> TuningJobOutcome {
    let name = svc.create_tuning_job(r).unwrap();
    svc.wait(&name).unwrap()
}

/// Canonical-config → final-value-bits map, the cache's own equality.
fn final_bits(out: &TuningJobOutcome) -> BTreeMap<String, Option<u64>> {
    out.evaluations
        .iter()
        .map(|e| {
            (
                amt::space::config_to_json_typed(&e.config).to_string(),
                e.final_value.map(f64::to_bits),
            )
        })
        .collect()
}

/// Satellite property: two warm-start children of one parent with
/// overlapping grids train each distinct config exactly once, counted
/// at the platform, and every hit is bit-identical to the recorded
/// outcome.
#[test]
fn warm_start_family_trains_each_distinct_config_exactly_once() {
    let svc = AmtService::new(PlatformConfig::noiseless());
    let parent = run(&svc, grid_request("fam-parent", 6, Vec::new()));
    assert_eq!(svc.telemetry_snapshot().counter("platform.trains"), Some(6));

    let a = run(&svc, grid_request("fam-child-a", 9, vec!["fam-parent".into()]));
    let b = run(&svc, grid_request("fam-child-b", 9, vec!["fam-parent".into()]));

    // 9 distinct configs in the family union, each trained exactly once:
    // grid points 0..6 by the parent, 6..9 by child A, nothing by child B
    let snap = svc.telemetry_snapshot();
    assert_eq!(snap.counter("platform.trains"), Some(9));
    assert_eq!(snap.counter("cache.hits"), Some(6 + 9));
    assert_eq!(snap.counter("cache.misses"), Some(6 + 3));
    assert_eq!(svc.store().eval_cache_hits(), 15);

    assert_eq!(a.evaluations.iter().filter(|e| e.cached).count(), 6);
    assert!(b.evaluations.iter().all(|e| e.cached && e.attempts == 0));
    assert_eq!(b.total_billable_seconds, 0.0, "cached evals must not bill");

    // hits replay the recorded values bit-exactly
    let parent_bits = final_bits(&parent);
    let a_bits = final_bits(&a);
    for (config, bits) in &parent_bits {
        assert_eq!(a_bits.get(config), Some(bits), "child A diverged on {config}");
    }
    assert_eq!(final_bits(&b), a_bits, "child B diverged from child A");
}

/// The cache is plain `MetadataStore` state, so it must ride WAL replay
/// and snapshot recovery: after close/reopen a third family member is
/// served entirely from the recovered cache and trains nothing.
#[test]
fn eval_cache_survives_close_and_reopen() {
    let dir = std::env::temp_dir().join(format!(
        "amt-eval-cache-{}-{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    let a_bits = {
        let svc = AmtService::open(&dir, PlatformConfig::noiseless()).unwrap();
        run(&svc, grid_request("dur-parent", 6, Vec::new()));
        let a = run(&svc, grid_request("dur-child-a", 9, vec!["dur-parent".into()]));
        let bits = final_bits(&a);
        svc.close().unwrap();
        bits
    };

    let svc = AmtService::open(&dir, PlatformConfig::noiseless()).unwrap();
    let b = run(&svc, grid_request("dur-child-b", 9, vec!["dur-parent".into()]));
    // every config is served from the recovered cache: the reopened
    // service never touches the platform (the counter is never created)
    assert_eq!(
        svc.telemetry_snapshot().counter("platform.trains").unwrap_or(0),
        0
    );
    assert_eq!(svc.store().eval_cache_hits(), 9);
    assert!(b.evaluations.iter().all(|e| e.cached));
    assert_eq!(final_bits(&b), a_bits);
    svc.close().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// CI gate (`scripts/ci.sh` pipeline_smoke): a 16-job BO fleet with the
/// speculative pipeline and the evaluation cache on. The first job
/// pipelines its proposals in the scheduler's idle tail; the other
/// fifteen — identical requests — are served entirely from the cache it
/// recorded, bit-identically.
#[test]
fn pipeline_smoke_16_bo_jobs_speculate_and_hit_cache() {
    let svc = AmtService::new(PlatformConfig::noiseless());
    let mk = |i: u64| TuningJobRequest {
        name: format!("pipe-smoke-{i:02}"),
        objective: "branin".into(),
        strategy: "bayesian".into(),
        max_training_jobs: 6,
        max_parallel_jobs: 1,
        seed: 99,
        speculative: true,
        eval_cache: true,
        ..Default::default()
    };
    let first = run(&svc, mk(0));
    // the full trajectory is recorded: the rest can run concurrently
    for i in 1..16 {
        svc.create_tuning_job(mk(i)).unwrap();
    }
    let rest: Vec<TuningJobOutcome> = (1..16u64)
        .map(|i| svc.wait(&format!("pipe-smoke-{i:02}")).unwrap())
        .collect();

    let snap = svc.telemetry_snapshot();
    assert!(
        snap.counter("strategy.speculation_hits").unwrap_or(0) > 0,
        "pipeline never committed a speculation"
    );
    assert!(snap.counter("cache.hits").unwrap_or(0) > 0, "cache never hit");
    assert_eq!(snap.counter("cache.hits"), Some(15 * 6));
    assert!(snap.histogram("strategy.speculate_us").map(|h| h.count).unwrap_or(0) > 0);

    for o in &rest {
        assert_eq!(o.evaluations.len(), first.evaluations.len());
        assert!(o.evaluations.iter().all(|e| e.cached));
        for (x, y) in first.evaluations.iter().zip(&o.evaluations) {
            assert_eq!(x.config, y.config, "{}: trajectory diverged", o.name);
            assert_eq!(
                x.final_value.map(f64::to_bits),
                y.final_value.map(f64::to_bits),
                "{}: cached value not bit-identical",
                o.name
            );
        }
    }
}

/// Both execution planes must agree: the same family on the loopback
/// remote pool produces bit-identical evaluations (cached flags
/// included) to the in-process scheduler. Seeds ship to workers on
/// `Assign`, and worker-recorded entries flow back through the capture
/// WAL, so sequential family members see the full cache either way.
#[test]
fn cache_dedupe_matches_across_execution_planes() {
    let family = |svc: &AmtService| {
        let parent = run(svc, grid_request("xp-parent", 6, Vec::new()));
        let a = run(svc, grid_request("xp-child-a", 9, vec!["xp-parent".into()]));
        let b = run(svc, grid_request("xp-child-b", 9, vec!["xp-parent".into()]));
        vec![parent, a, b]
    };

    let local = AmtService::new(PlatformConfig::noiseless());
    let local_outcomes = family(&local);

    let mut transports = Vec::new();
    let mut handles = Vec::new();
    for i in 0..2 {
        let (t, _fault, h) = spawn_loopback_worker(&format!("cache-{i}"));
        transports.push(t);
        handles.push(h);
    }
    let remote = AmtService::with_remote_workers(PlatformConfig::noiseless(), transports);
    let remote_outcomes = family(&remote);

    for (l, r) in local_outcomes.iter().zip(&remote_outcomes) {
        assert_eq!(l.evaluations.len(), r.evaluations.len(), "{}", l.name);
        for (x, y) in l.evaluations.iter().zip(&r.evaluations) {
            assert_eq!(x.training_job_name, y.training_job_name);
            assert_eq!(x.config, y.config);
            assert_eq!(
                x.final_value.map(f64::to_bits),
                y.final_value.map(f64::to_bits),
                "{}: value diverged across planes",
                x.training_job_name
            );
            assert_eq!(x.ended_at.to_bits(), y.ended_at.to_bits());
            assert_eq!(x.cached, y.cached, "{}: cached flag diverged", x.training_job_name);
            assert_eq!(x.attempts, y.attempts);
        }
    }
    assert!(remote_outcomes[2].evaluations.iter().all(|e| e.cached));

    drop(remote);
    for h in handles {
        let _ = h.join();
    }
}
