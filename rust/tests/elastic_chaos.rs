//! Elastic-fleet chaos tests (DESIGN.md §13): dynamic membership,
//! graceful drain and work stealing under fault injection, all over the
//! loopback transport so every leg runs the full wire path
//! deterministically in one process.
//!
//! The centerpiece is the chaos soak: 1000 tuning jobs across a fleet
//! that loses two workers to kills, gains one mid-run, and drains one
//! gracefully — with zero lost or duplicated work, zero re-executed
//! proposals on the snapshot-path migrations (drain + steal), and a
//! final store bit-identical to an uninterrupted single-fleet run. The
//! smaller `fast_chaos_smoke` variant is the CI gate (`scripts/ci.sh`).

use std::sync::Arc;
use std::time::{Duration, Instant};

use amt::api::AmtService;
use amt::config::TuningJobRequest;
use amt::distributed::leader::{RemoteConfig, RemoteWorkerPool};
use amt::distributed::proto::Message;
use amt::distributed::transport::{loopback_pair, LoopbackFault, Transport};
use amt::distributed::worker::spawn_loopback_worker;
use amt::metrics::MetricsService;
use amt::platform::PlatformConfig;
use amt::store::MetadataStore;
use amt::workflow::ExecutionStatus;

struct WorkerSet {
    faults: Vec<Arc<LoopbackFault>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

fn spawn_workers(n: usize, tag: &str) -> (Vec<Box<dyn Transport>>, WorkerSet) {
    let mut transports = Vec::new();
    let mut faults = Vec::new();
    let mut handles = Vec::new();
    for i in 0..n {
        let (t, fault, h) = spawn_loopback_worker(&format!("{tag}-{i}"));
        transports.push(t);
        faults.push(fault);
        handles.push(h);
    }
    (transports, WorkerSet { faults, handles })
}

impl WorkerSet {
    fn join(self) {
        for h in self.handles {
            let _ = h.join();
        }
    }
}

fn chaos_requests(tag: &str, n: usize, evals: u32, seed_base: u64) -> Vec<TuningJobRequest> {
    (0..n as u64)
        .map(|i| TuningJobRequest {
            name: format!("{tag}-{i:04}"),
            objective: "branin".into(),
            strategy: "random".into(),
            max_training_jobs: evals,
            max_parallel_jobs: 2,
            seed: seed_base + i,
            ..Default::default()
        })
        .collect()
}

/// Run the same requests on the in-process pool: the uninterrupted
/// reference every chaos run must match in bits.
fn reference_run(requests: &[TuningJobRequest]) -> AmtService {
    let svc = AmtService::new(PlatformConfig::noiseless());
    for r in requests {
        svc.create_tuning_job(r.clone()).unwrap();
    }
    for r in requests {
        svc.wait(&r.name).unwrap();
    }
    svc
}

fn assert_services_identical(local: &AmtService, remote: &AmtService) {
    assert_eq!(
        local.store().snapshot(),
        remote.store().snapshot(),
        "store contents (values + versions) diverged"
    );
    let streams = local.metrics().list_streams("");
    assert_eq!(streams, remote.metrics().list_streams(""), "stream sets diverged");
    for s in &streams {
        let a: Vec<(u64, u64)> = local
            .metrics()
            .series(s)
            .iter()
            .map(|p| (p.time.to_bits(), p.value.to_bits()))
            .collect();
        let b: Vec<(u64, u64)> = remote
            .metrics()
            .series(s)
            .iter()
            .map(|p| (p.time.to_bits(), p.value.to_bits()))
            .collect();
        assert_eq!(a, b, "metric series '{s}' diverged");
    }
}

/// Wait until the fleet has served at least `polls` slices across the
/// given jobs (the chaos event must land mid-run, not before it starts).
fn await_polls(pool: &RemoteWorkerPool, requests: &[TuningJobRequest], polls: u64) {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let total: u64 = requests.iter().filter_map(|r| pool.poll_count(&r.name)).sum();
        if total >= polls {
            return;
        }
        assert!(Instant::now() < deadline, "fleet never got going");
        std::thread::yield_now();
    }
}

fn await_live(pool: &RemoteWorkerPool, n: usize) {
    let deadline = Instant::now() + Duration::from_secs(60);
    while pool.live_workers() != n {
        assert!(Instant::now() < deadline, "live_workers never reached {n}");
        std::thread::yield_now();
    }
}

/// The CI chaos smoke (`scripts/ci.sh`): 64 jobs over 2 workers; one
/// worker killed mid-run, a fresh one joins, the other original drains
/// gracefully. No lost or duplicated work, the drain/steal legs replay
/// nothing, and the final state matches an uninterrupted run in bits.
#[test]
fn fast_chaos_smoke_64_jobs_kill_join_drain() {
    let requests = chaos_requests("smoke", 64, 3, 5000);
    let reference = reference_run(&requests);

    let (transports, workers) = spawn_workers(2, "smoke");
    let mut svc = AmtService::new(PlatformConfig::noiseless());
    svc.attach_remote_workers(
        transports,
        RemoteConfig { batch_steps: 8, ..RemoteConfig::default() },
    );
    for r in &requests {
        svc.create_tuning_job(r.clone()).unwrap();
    }
    let pool = svc.remote_pool().unwrap();
    await_polls(&pool, &requests, 8);

    // kill #1: worker 0 dies; its jobs requeue onto the survivor.
    // on_worker_death retires the lane and requeues synchronously, so
    // once live drops the repair (and any replays it cost) is complete.
    workers.faults[0].kill();
    await_live(&pool, 1);
    let replays_after_kill = pool.replayed_proposals();

    // join: a fresh worker dials in mid-run and gets stolen work
    let (late_t, _late_fault, late_h) = spawn_loopback_worker("smoke-late");
    svc.add_remote_worker(late_t).unwrap();

    // graceful drain of the other original worker: its queued + running
    // jobs migrate from checkpoints — nothing re-executes
    assert!(svc.drain_remote_worker(1), "lane 1 should be drainable");

    let mut outcomes = Vec::new();
    for r in &requests {
        outcomes.push(svc.wait(&r.name).unwrap());
    }
    for o in &outcomes {
        assert_eq!(o.status, ExecutionStatus::Succeeded, "{} failed", o.name);
    }
    assert_eq!(pool.joins(), 1, "late worker not counted as a join");
    // the drains counter lands after the drain handshake, which can
    // trail the last job completion by a moment
    let deadline = Instant::now() + Duration::from_secs(30);
    while pool.drains() == 0 {
        assert!(Instant::now() < deadline, "drain never completed");
        std::thread::yield_now();
    }
    assert_eq!(
        pool.replayed_proposals(),
        replays_after_kill,
        "join/steal/drain legs must replay nothing (snapshot path only)"
    );
    assert_services_identical(&reference, &svc);
    assert_eq!(svc.running_jobs(), 0);
    drop(pool);
    drop(svc);
    workers.join();
    let _ = late_h.join();
}

/// The acceptance soak: 1000 jobs; two kills, one late join, one
/// graceful drain — all mid-run. Every job succeeds exactly once, the
/// elastic legs replay zero proposals, and the final store is
/// bit-identical to an uninterrupted run.
#[test]
fn chaos_soak_1000_jobs_survives_kills_join_and_drain() {
    let requests = chaos_requests("chaos", 1000, 2, 9000);
    let reference = reference_run(&requests);

    let (transports, workers) = spawn_workers(3, "chaos");
    let mut svc = AmtService::new(PlatformConfig::noiseless());
    svc.attach_remote_workers(
        transports,
        RemoteConfig { batch_steps: 16, ..RemoteConfig::default() },
    );
    for r in &requests {
        svc.create_tuning_job(r.clone()).unwrap();
    }
    let pool = svc.remote_pool().unwrap();
    await_polls(&pool, &requests, 32);

    // kill #1 (abrupt death: lease/requeue machinery)
    workers.faults[0].kill();
    await_live(&pool, 2);
    let replays_after_kill = pool.replayed_proposals();

    // late join: the new lane's first Hello triggers a rebalance that
    // steals queued work from the (deep) surviving lanes
    let (late_t, _late_fault, late_h) = spawn_loopback_worker("chaos-late");
    svc.add_remote_worker(late_t).unwrap();
    let deadline = Instant::now() + Duration::from_secs(60);
    while pool.steals() == 0 {
        assert!(Instant::now() < deadline, "join never triggered a steal");
        std::thread::yield_now();
    }
    assert_eq!(
        pool.replayed_proposals(),
        replays_after_kill,
        "steals must move work without re-executing it"
    );

    // graceful drain of an original worker
    assert!(svc.drain_remote_worker(1), "lane 1 should be drainable");
    let deadline = Instant::now() + Duration::from_secs(60);
    while pool.drains() == 0 {
        assert!(Instant::now() < deadline, "drain never completed");
        std::thread::yield_now();
    }
    assert_eq!(
        pool.replayed_proposals(),
        replays_after_kill,
        "a graceful drain must migrate from checkpoints, replaying nothing"
    );

    // kill #2: another abrupt death; the late joiner carries the rest
    workers.faults[2].kill();

    let mut outcomes = Vec::new();
    for r in &requests {
        outcomes.push(svc.wait(&r.name).unwrap());
    }
    // zero lost work: every job reaches Succeeded exactly once; zero
    // duplicated work: the bit-identity check below would catch any
    // double-applied evaluation as a version/value divergence
    for o in &outcomes {
        assert_eq!(o.status, ExecutionStatus::Succeeded, "{} failed", o.name);
        assert_eq!(o.evaluations.len(), 2, "{} wrong evaluation count", o.name);
    }
    assert!(pool.joins() >= 1, "soak must exercise a late join");
    assert!(pool.drains() >= 1, "soak must exercise a graceful drain");
    assert!(pool.steals() >= 1, "soak must exercise work stealing");
    assert_services_identical(&reference, &svc);
    assert_eq!(svc.running_jobs(), 0);
    drop(pool);
    drop(svc);
    workers.join();
    let _ = late_h.join();
}

/// Membership edge: a worker that says Hello during an active run gets
/// queued work stolen onto it — and stealing re-executes nothing.
#[test]
fn late_hello_gets_stolen_work() {
    let requests = chaos_requests("steal", 12, 4, 2000);
    let (transports, workers) = spawn_workers(1, "steal");
    let mut svc = AmtService::new(PlatformConfig::noiseless());
    svc.attach_remote_workers(
        transports,
        RemoteConfig { batch_steps: 4, ..RemoteConfig::default() },
    );
    for r in &requests {
        svc.create_tuning_job(r.clone()).unwrap();
    }
    let pool = svc.remote_pool().unwrap();
    await_polls(&pool, &requests, 2);

    let (late_t, _late_fault, late_h) = spawn_loopback_worker("steal-late");
    let lane = svc.add_remote_worker(late_t).unwrap();
    assert_eq!(lane, 1, "late worker should get the next lane index");

    for r in &requests {
        let out = svc.wait(&r.name).unwrap();
        assert_eq!(out.status, ExecutionStatus::Succeeded, "{} failed", r.name);
    }
    assert_eq!(pool.joins(), 1);
    assert!(pool.steals() >= 1, "a 12-deep lane vs an idle joiner must steal");
    assert_eq!(pool.replayed_proposals(), 0, "steals must not re-execute proposals");
    assert_eq!(pool.scratch_requeues(), 0, "no deaths: nothing may take the scratch path");
    drop(pool);
    drop(svc);
    workers.join();
    let _ = late_h.join();
}

/// Membership edge: a worker killed *while draining* falls back to the
/// death-repair path — jobs still finish exactly once whichever leg
/// (drain migration or death requeue) wins the race.
#[test]
fn worker_killed_mid_drain_falls_back_to_death_repair() {
    let requests = chaos_requests("middrain", 8, 3, 6000);
    let reference = reference_run(&requests);

    let (transports, workers) = spawn_workers(2, "middrain");
    let mut svc = AmtService::new(PlatformConfig::noiseless());
    svc.attach_remote_workers(
        transports,
        RemoteConfig { batch_steps: 8, ..RemoteConfig::default() },
    );
    for r in &requests {
        svc.create_tuning_job(r.clone()).unwrap();
    }
    let pool = svc.remote_pool().unwrap();
    await_polls(&pool, &requests, 4);

    // drain and kill the same worker back to back: the driver may see
    // the drain flag first (graceful leg) or the dead link first (repair
    // leg) — both must converge on the survivor with no lost work
    assert!(svc.drain_remote_worker(0));
    workers.faults[0].kill();

    for r in &requests {
        let out = svc.wait(&r.name).unwrap();
        assert_eq!(out.status, ExecutionStatus::Succeeded, "{} failed", r.name);
    }
    await_live(&pool, 1);
    assert_services_identical(&reference, &svc);
    drop(pool);
    drop(svc);
    workers.join();
}

/// Membership edge: two workers announcing the same name — the second
/// Hello is rejected with a hard `Deny` (the reconnect loop must exit,
/// not retry) and the fleet keeps exactly one live lane.
#[test]
fn duplicate_worker_names_rejected() {
    let store = Arc::new(MetadataStore::new());
    let metrics = Arc::new(MetricsService::new());
    let pool = RemoteWorkerPool::new(
        Vec::new(),
        Arc::clone(&store),
        metrics,
        None,
        RemoteConfig::default(),
    );

    // drive the protocol by hand from the worker ends so both lanes
    // claim the same name (real workers embed their pid in the label)
    let (leader0, mut end0, _f0) = loopback_pair("dup-0");
    let (leader1, mut end1, _f1) = loopback_pair("dup-1");
    assert_eq!(pool.add_worker(Box::new(leader0)), 0);
    assert_eq!(pool.add_worker(Box::new(leader1)), 1);

    end0.send(&Message::Hello { worker: "dup".into(), backend: "native".into(), proto: 2 }).unwrap();
    // wait for lane 0's Hello to be accepted before contending
    let deadline = Instant::now() + Duration::from_secs(30);
    while pool.lane_backends().first() != Some(&Some("native".to_string())) {
        assert!(Instant::now() < deadline, "first Hello never accepted");
        std::thread::yield_now();
    }

    end1.send(&Message::Hello { worker: "dup".into(), backend: "native".into(), proto: 2 }).unwrap();
    let deadline = Instant::now() + Duration::from_secs(30);
    let verdict = loop {
        assert!(Instant::now() < deadline, "leader never answered the duplicate");
        match end1.recv(Duration::from_millis(200)).unwrap() {
            Some(msg) => break msg,
            None => continue,
        }
    };
    match verdict {
        Message::Deny { reason } => {
            assert!(reason.contains("dup"), "Deny should name the offender: {reason}")
        }
        other => panic!("expected Deny for a duplicate name, got {other:?}"),
    }
    // Deny is sent just before the lane is retired: poll for the count
    let deadline = Instant::now() + Duration::from_secs(30);
    while pool.live_workers() != 1 {
        assert!(Instant::now() < deadline, "duplicate lane never retired");
        std::thread::yield_now();
    }
    assert_eq!(pool.joins(), 2, "both admissions count as joins");
    drop(pool);
}

/// Membership edge: draining the *last* lane parks its jobs instead of
/// failing them — they stay InProgress until a new worker joins, then
/// resume from their checkpoints with zero replays.
#[test]
fn drain_of_last_lane_parks_jobs_until_a_worker_joins() {
    let requests = chaos_requests("park", 3, 12, 8000);
    let (transports, workers) = spawn_workers(1, "park");
    let mut svc = AmtService::new(PlatformConfig::noiseless());
    svc.attach_remote_workers(
        transports,
        RemoteConfig { batch_steps: 4, ..RemoteConfig::default() },
    );
    for r in &requests {
        svc.create_tuning_job(r.clone()).unwrap();
    }
    let pool = svc.remote_pool().unwrap();
    await_polls(&pool, &requests, 3);

    assert!(svc.drain_remote_worker(0));
    let deadline = Instant::now() + Duration::from_secs(60);
    while pool.drains() == 0 {
        assert!(Instant::now() < deadline, "drain never completed");
        std::thread::yield_now();
    }
    assert_eq!(pool.live_workers(), 0);
    // no surviving lane: the jobs must be parked, not failed
    assert_eq!(svc.running_jobs(), 3, "drained jobs must stay pending");
    for r in &requests {
        assert!(
            pool.try_outcome(&r.name).is_none(),
            "{} must not have a (failure) outcome while parked",
            r.name
        );
    }

    // a fresh worker joins: the parked jobs place onto it and finish
    let (late_t, _late_fault, late_h) = spawn_loopback_worker("park-late");
    svc.add_remote_worker(late_t).unwrap();
    for r in &requests {
        let out = svc.wait(&r.name).unwrap();
        assert_eq!(out.status, ExecutionStatus::Succeeded, "{} failed", r.name);
        assert_eq!(out.evaluations.len(), 12);
    }
    assert_eq!(pool.replayed_proposals(), 0, "parked jobs must resume from checkpoints");
    drop(pool);
    drop(svc);
    workers.join();
    let _ = late_h.join();
}

/// Speculation chaos leg (DESIGN.md §17): the kill/join/drain smoke with
/// the proposal pipeline enabled on every job. Speculation must be
/// bit-transparent under elastic chaos — the fleet's final state matches
/// an uninterrupted pipelined in-process reference, and the snapshot
/// legs (join/steal/drain) still replay zero proposals.
#[test]
fn pipelined_kill_join_drain_matches_uninterrupted_pipelined_reference() {
    let mut requests = chaos_requests("pipe", 24, 4, 7000);
    for (i, r) in requests.iter_mut().enumerate() {
        r.speculative = true;
        // a few BO jobs exercise the discard path (value-dependent
        // proposals) alongside random's always-commit path
        if i % 6 == 0 {
            r.strategy = "bayesian".into();
            r.max_parallel_jobs = 1;
        }
    }
    let reference = reference_run(&requests);
    assert!(
        reference
            .telemetry_snapshot()
            .counter("strategy.speculation_hits")
            .unwrap_or(0)
            > 0,
        "pipeline never engaged in the reference run"
    );

    let (transports, workers) = spawn_workers(2, "pipe");
    let mut svc = AmtService::new(PlatformConfig::noiseless());
    svc.attach_remote_workers(
        transports,
        RemoteConfig { batch_steps: 8, ..RemoteConfig::default() },
    );
    for r in &requests {
        svc.create_tuning_job(r.clone()).unwrap();
    }
    let pool = svc.remote_pool().unwrap();
    await_polls(&pool, &requests, 8);

    // kill #1: abrupt death mid-pipeline; the survivor resumes the
    // victims from their last delta-acked checkpoints (speculation in
    // flight at the boundary thaws with the actor or re-speculates —
    // both bit-identical)
    workers.faults[0].kill();
    await_live(&pool, 1);
    let replays_after_kill = pool.replayed_proposals();

    // join + graceful drain: pure snapshot paths
    let (late_t, _late_fault, late_h) = spawn_loopback_worker("pipe-late");
    svc.add_remote_worker(late_t).unwrap();
    assert!(svc.drain_remote_worker(1), "lane 1 should be drainable");

    let mut outcomes = Vec::new();
    for r in &requests {
        outcomes.push(svc.wait(&r.name).unwrap());
    }
    for o in &outcomes {
        assert_eq!(o.status, ExecutionStatus::Succeeded, "{} failed", o.name);
        assert_eq!(o.evaluations.len(), 4, "{} wrong evaluation count", o.name);
    }
    let deadline = Instant::now() + Duration::from_secs(30);
    while pool.drains() == 0 {
        assert!(Instant::now() < deadline, "drain never completed");
        std::thread::yield_now();
    }
    assert_eq!(
        pool.replayed_proposals(),
        replays_after_kill,
        "snapshot legs must replay zero proposals with the pipeline on"
    );
    assert_services_identical(&reference, &svc);
    assert_eq!(svc.running_jobs(), 0);
    drop(pool);
    drop(svc);
    workers.join();
    let _ = late_h.join();
}
