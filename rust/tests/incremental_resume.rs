//! Incremental-resume integration tests (DESIGN.md §12): versioned v1
//! resume snapshots make durable recovery and worker-death requeue
//! O(remaining work).
//!
//! The acceptance property: a BO tuning job killed at **every**
//! Pending-boundary checkpoint resumes through the snapshot fast path —
//! zero strategy proposals re-executed — and finishes with a
//! bit-identical trajectory, evaluation records, metric series and store
//! contents (values *and* versions) versus the uninterrupted run, on
//! both failure legs (durable recovery-on-open and the distributed
//! leader's worker-death requeue). Legacy v0 cursor-only checkpoints
//! still recover via the pre-existing scratch-replay path, bit-identical
//! to pre-refactor behavior.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use amt::api::AmtService;
use amt::config::TuningJobRequest;
use amt::coordinator::{checkpoint_cursor, ResumeSnapshot};
use amt::distributed::leader::RemoteConfig;
use amt::distributed::worker::spawn_loopback_worker;
use amt::durability::wal::{Wal, WalRecord, WAL_FILE};
use amt::gp::NativeBackend;
use amt::platform::PlatformConfig;
use amt::scheduler::SchedulerConfig;
use amt::workflow::ExecutionStatus;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "amt-resume-it-{tag}-{}-{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn open_svc(dir: &PathBuf) -> AmtService {
    // small batch slices force plenty of Pending boundaries (checkpoints)
    AmtService::open_with_options(
        dir,
        PlatformConfig::noiseless(),
        Arc::new(NativeBackend),
        SchedulerConfig { workers: 2, batch_steps: 8 },
    )
    .unwrap()
}

fn bo_request(name: &str) -> TuningJobRequest {
    TuningJobRequest {
        name: name.into(),
        objective: "branin".into(),
        strategy: "bayesian".into(),
        max_training_jobs: 5,
        max_parallel_jobs: 2,
        seed: 23,
        ..Default::default()
    }
}

/// Everything the identity comparison looks at, in bits.
struct Fingerprint {
    store_snapshot: String,
    trajectory: Vec<(u64, u64)>,
    evaluations: Vec<(String, Option<u64>, u64)>,
    eval_series: Vec<(u64, u64)>,
    epoch_series: Vec<(u64, u64)>,
}

fn fingerprint(
    svc: &AmtService,
    outcome: &amt::coordinator::TuningJobOutcome,
    name: &str,
) -> Fingerprint {
    let series_bits = |stream: &str| -> Vec<(u64, u64)> {
        svc.metrics()
            .series(stream)
            .iter()
            .map(|p| (p.time.to_bits(), p.value.to_bits()))
            .collect()
    };
    Fingerprint {
        store_snapshot: svc.store().snapshot(),
        trajectory: outcome
            .best_over_time(true)
            .iter()
            .map(|(t, v)| (t.to_bits(), v.to_bits()))
            .collect(),
        evaluations: outcome
            .evaluations
            .iter()
            .map(|e| {
                (
                    e.training_job_name.clone(),
                    e.final_value.map(f64::to_bits),
                    e.ended_at.to_bits(),
                )
            })
            .collect(),
        eval_series: series_bits(&format!("{name}/evaluations")),
        epoch_series: series_bits(&format!("{name}-train-0000/objective")),
    }
}

fn assert_identical(a: &Fingerprint, b: &Fingerprint, what: &str) {
    assert_eq!(a.store_snapshot, b.store_snapshot, "{what}: store diverged");
    assert_eq!(a.trajectory, b.trajectory, "{what}: trajectory diverged");
    assert_eq!(a.evaluations, b.evaluations, "{what}: evaluations diverged");
    assert_eq!(a.eval_series, b.eval_series, "{what}: evaluations series diverged");
    assert_eq!(a.epoch_series, b.epoch_series, "{what}: epoch series diverged");
}

/// Acceptance property, durable-recovery leg: kill right after **every**
/// v1 checkpoint of a BO job ⇒ recovery takes the snapshot fast path
/// (zero proposals re-executed) and the finished run is bit-identical.
#[test]
fn bo_job_killed_at_every_checkpoint_fast_resumes_bit_identical() {
    let name = "resume-bo";
    let dir = tmpdir("ref");
    let svc = open_svc(&dir);
    svc.create_tuning_job(bo_request(name)).unwrap();
    let outcome = svc.wait(name).unwrap();
    svc.wal().unwrap().commit().unwrap();
    let reference = fingerprint(&svc, &outcome, name);
    drop(svc); // crash-style teardown: no close(), no shard snapshot

    let bytes = std::fs::read(dir.join(WAL_FILE)).unwrap();
    let scan = Wal::scan(&dir.join(WAL_FILE)).unwrap();
    let ckpt_cuts: Vec<usize> = scan
        .records
        .iter()
        .enumerate()
        .filter(|(_, (_, r))| matches!(r, WalRecord::Checkpoint { .. }))
        .map(|(i, _)| i)
        .collect();
    assert!(ckpt_cuts.len() >= 5, "expected many Pending checkpoints, got {ckpt_cuts:?}");

    for (n, idx) in ckpt_cuts.iter().enumerate() {
        let len = scan.frame_ends[*idx] as usize;
        let what = format!("kill at checkpoint {}/{}", n + 1, ckpt_cuts.len());
        let cut_dir = tmpdir("cut");
        std::fs::write(cut_dir.join(WAL_FILE), &bytes[..len]).unwrap();
        let svc = open_svc(&cut_dir);
        assert!(
            svc.recovered_jobs().contains(&name.to_string()),
            "{what}: job must resume"
        );
        let stats = svc.recovery_stats();
        assert_eq!(stats.fast_resumed, 1, "{what}: snapshot fast path not taken");
        assert_eq!(stats.scratch_resumed, 0, "{what}: unexpected scratch replay");
        assert_eq!(
            stats.replayed_proposals, 0,
            "{what}: proposals re-executed on the fast path"
        );
        let outcome = svc.wait(name).unwrap();
        let recovered = fingerprint(&svc, &outcome, name);
        assert_identical(&reference, &recovered, &what);
        drop(svc);
        let _ = std::fs::remove_dir_all(&cut_dir);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Cuts that land *inside* a poll slice (between a checkpoint and the
/// next) also fast-resume: recovery skips the partial post-checkpoint
/// tail and the resumed execution re-produces it exactly.
#[test]
fn mid_slice_cuts_fast_resume_after_first_checkpoint() {
    let name = "resume-midslice";
    let dir = tmpdir("mid-ref");
    let svc = open_svc(&dir);
    let mut request = bo_request(name);
    request.strategy = "random".into();
    request.max_training_jobs = 6;
    svc.create_tuning_job(request).unwrap();
    let outcome = svc.wait(name).unwrap();
    svc.wal().unwrap().commit().unwrap();
    let reference = fingerprint(&svc, &outcome, name);
    drop(svc);

    let bytes = std::fs::read(dir.join(WAL_FILE)).unwrap();
    let scan = Wal::scan(&dir.join(WAL_FILE)).unwrap();
    let first_ckpt = scan
        .records
        .iter()
        .position(|(_, r)| matches!(r, WalRecord::Checkpoint { .. }))
        .expect("at least one checkpoint");
    let last = scan.records.len() - 1;
    // a spread of mid-slice record boundaries strictly after the first
    // checkpoint and before the terminal record
    for cut in [first_ckpt + 1, (first_ckpt + last) / 2, last - 1] {
        let len = scan.frame_ends[cut] as usize;
        let what = format!("mid-slice cut after record {cut}");
        let cut_dir = tmpdir("mid-cut");
        std::fs::write(cut_dir.join(WAL_FILE), &bytes[..len]).unwrap();
        let svc = open_svc(&cut_dir);
        assert!(svc.recovered_jobs().contains(&name.to_string()), "{what}: no resume");
        let stats = svc.recovery_stats();
        assert_eq!(stats.fast_resumed, 1, "{what}: fast path not taken");
        assert_eq!(stats.replayed_proposals, 0, "{what}: proposals re-executed");
        let outcome = svc.wait(name).unwrap();
        let recovered = fingerprint(&svc, &outcome, name);
        assert_identical(&reference, &recovered, &what);
        drop(svc);
        let _ = std::fs::remove_dir_all(&cut_dir);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Rewrite a WAL's v1 checkpoints into legacy v0 (bare-cursor) records,
/// preserving record order; LSNs renumber from 1, which recovery
/// tolerates (no manifest in these tests).
fn rewrite_checkpoints_to_v0(dir: &PathBuf, bytes: &[u8]) {
    let scan = Wal::decode_frames(bytes);
    let wal = Wal::create(dir).unwrap();
    for (_, rec) in &scan.records {
        let rec = match rec {
            WalRecord::Checkpoint { job, exec } => {
                let cursor = checkpoint_cursor(exec).expect("cursor parses").to_json();
                assert!(
                    ResumeSnapshot::from_json(&cursor).is_none(),
                    "v0 payload must not parse as a snapshot"
                );
                WalRecord::Checkpoint { job: job.clone(), exec: cursor }
            }
            other => other.clone(),
        };
        wal.append(&rec);
    }
    wal.commit().unwrap();
}

/// Satellite: a WAL containing only legacy v0 cursor-only checkpoints
/// (hand-rebuilt frames) recovers via scratch replay, bit-identical to
/// pre-refactor behavior — the migration guarantee.
#[test]
fn legacy_v0_checkpoints_recover_via_scratch_replay_bit_identical() {
    let name = "resume-legacy";
    let dir = tmpdir("legacy-ref");
    let svc = open_svc(&dir);
    svc.create_tuning_job(bo_request(name)).unwrap();
    let outcome = svc.wait(name).unwrap();
    svc.wal().unwrap().commit().unwrap();
    let reference = fingerprint(&svc, &outcome, name);
    drop(svc);

    let bytes = std::fs::read(dir.join(WAL_FILE)).unwrap();
    let scan = Wal::scan(&dir.join(WAL_FILE)).unwrap();
    let n = scan.records.len();
    for cut in [n / 3, 2 * n / 3] {
        let what = format!("v0 cut after record {cut}/{n}");
        let cut_dir = tmpdir("legacy-cut");
        rewrite_checkpoints_to_v0(&cut_dir, &bytes[..scan.frame_ends[cut - 1] as usize]);
        let svc = open_svc(&cut_dir);
        assert!(svc.recovered_jobs().contains(&name.to_string()), "{what}: no resume");
        let stats = svc.recovery_stats();
        assert_eq!(stats.fast_resumed, 0, "{what}: v0 must not fast-path");
        assert_eq!(stats.scratch_resumed, 1, "{what}: scratch replay expected");
        assert!(
            stats.replayed_proposals > 0,
            "{what}: scratch replay re-executes past proposals"
        );
        let outcome = svc.wait(name).unwrap();
        let recovered = fingerprint(&svc, &outcome, name);
        assert_identical(&reference, &recovered, &what);
        drop(svc);
        let _ = std::fs::remove_dir_all(&cut_dir);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance property, worker-death leg: with every job checkpointed
/// at least once (deltas acked), a killed worker's jobs requeue from
/// their snapshots — zero proposals re-executed — and the final state is
/// bit-identical to an uninterrupted run.
#[test]
fn worker_death_requeues_from_snapshot_bit_identical() {
    let requests: Vec<TuningJobRequest> = (0..4u64)
        .map(|i| TuningJobRequest {
            name: format!("wd-{i}"),
            objective: "branin".into(),
            strategy: if i == 0 { "bayesian" } else { "random" }.into(),
            max_training_jobs: if i == 0 { 4 } else { 8 },
            max_parallel_jobs: 2,
            seed: 5000 + i,
            ..Default::default()
        })
        .collect();

    // uninterrupted reference on the in-process pool
    let reference = AmtService::new(PlatformConfig::noiseless());
    for r in &requests {
        reference.create_tuning_job(r.clone()).unwrap();
    }
    let mut ref_outcomes = Vec::new();
    for r in &requests {
        ref_outcomes.push(reference.wait(&r.name).unwrap());
    }

    let mut transports = Vec::new();
    let mut faults = Vec::new();
    let mut handles = Vec::new();
    for i in 0..2 {
        let (t, fault, h) = spawn_loopback_worker(&format!("wd-{i}"));
        transports.push(t);
        faults.push(fault);
        handles.push(h);
    }
    let mut svc = AmtService::new(PlatformConfig::noiseless());
    svc.attach_remote_workers(
        transports,
        RemoteConfig { batch_steps: 8, ..RemoteConfig::default() },
    );
    for r in &requests {
        svc.create_tuning_job(r.clone()).unwrap();
    }
    // wait until every job has served at least two slices (⇒ its first
    // delta-acked checkpoint reached the leader), then kill worker 0
    let pool = svc.remote_pool().unwrap();
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let all_checkpointed = requests
            .iter()
            .all(|r| pool.poll_count(&r.name).unwrap_or(0) >= 2 || pool.try_outcome(&r.name).is_some());
        if all_checkpointed {
            break;
        }
        assert!(Instant::now() < deadline, "spike never got going");
        std::thread::yield_now();
    }
    faults[0].kill();

    let mut outcomes = Vec::new();
    for r in &requests {
        outcomes.push(svc.wait(&r.name).unwrap());
    }
    assert_eq!(pool.live_workers(), 1);
    // every requeue the kill caused came from a snapshot
    assert_eq!(pool.scratch_requeues(), 0, "expected snapshot-only requeues");
    assert_eq!(pool.replayed_proposals(), 0, "proposals re-executed after the kill");
    assert!(
        pool.snapshot_requeues() >= 1,
        "the killed worker must have hosted at least one unfinished job"
    );

    for (a, b) in ref_outcomes.iter().zip(&outcomes) {
        assert_eq!(b.status, ExecutionStatus::Succeeded, "{} failed", b.name);
        let bits = |o: &amt::coordinator::TuningJobOutcome| -> Vec<(String, Option<u64>, u64)> {
            o.evaluations
                .iter()
                .map(|e| {
                    (
                        e.training_job_name.clone(),
                        e.final_value.map(f64::to_bits),
                        e.ended_at.to_bits(),
                    )
                })
                .collect()
        };
        assert_eq!(bits(a), bits(b), "{}: trajectory diverged after worker kill", a.name);
        assert_eq!(a.total_seconds.to_bits(), b.total_seconds.to_bits());
    }
    assert_eq!(
        reference.store().snapshot(),
        svc.store().snapshot(),
        "store contents (values + versions) diverged after snapshot requeue"
    );
    drop(pool);
    drop(svc);
    for h in handles {
        let _ = h.join();
    }
}
