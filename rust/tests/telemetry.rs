//! Telemetry-plane integration tests (DESIGN.md §15), isolated in their
//! own test binary because they exercise PROCESS-GLOBAL state: the
//! counting `#[global_allocator]` for the zero-overhead assertion, and
//! the `telemetry::set_enabled` / `trace::set_sampling` switches that
//! other binaries' tests must never see flipped. Within this binary,
//! every global toggle is confined to a single `#[test]` and restored
//! before it returns.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use amt::telemetry::{self, Histogram, Registry};

// --- counting allocator: per-thread allocation counter over System ---
//
// Thread-local so parallel test threads don't pollute each other's
// counts; `try_with` because the allocator can be called during TLS
// teardown, when the Cell is already gone.

struct CountingAlloc;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocs_on_this_thread() -> u64 {
    ALLOCS.try_with(Cell::get).unwrap_or(0)
}

/// Deterministic pseudo-random sample stream (splitmix64) so the
/// property test needs no RNG seed plumbing.
fn samples(seed: u64, n: usize) -> Vec<u64> {
    let mut state = seed;
    (0..n)
        .map(|_| {
            state = state.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = state;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z = z ^ (z >> 31);
            // spread across the interesting range: sub-bucket exact
            // values, mid-range, and large tails
            match z % 4 {
                0 => z % 8,
                1 => z % 1_000,
                2 => z % 1_000_000,
                _ => z % 10_000_000_000,
            }
        })
        .collect()
}

/// Reference quantile matching the histogram's convention: the
/// rank-`ceil(q·n)` sample of the sorted vector.
fn reference_quantile(sorted: &[u64], q: f64) -> u64 {
    let n = sorted.len() as u64;
    let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
    sorted[rank as usize - 1]
}

/// A reported quantile must sit at or below the true sample value, and
/// within one log-bucket's relative width (≤ 1/4) of it.
fn assert_within_one_bucket(reported: u64, reference: u64, what: &str) {
    assert!(
        reported <= reference,
        "{what}: reported {reported} above true sample {reference}"
    );
    let slack = reference as f64 * 0.25 + 1.0;
    assert!(
        (reference - reported) as f64 <= slack,
        "{what}: reported {reported} more than one bucket below {reference}"
    );
}

/// Histogram correctness property: for random sample sets split across
/// shards, (1) quantiles are identical no matter how the shards are
/// merged (commutative + associative bucket addition), and (2) every
/// quantile matches a sorted-vector reference within one bucket's
/// relative error, with min/max/count exact.
#[test]
fn histogram_merge_is_order_invariant_and_tracks_reference() {
    for seed in [1u64, 7, 42, 1234] {
        let values = samples(seed, 4_000);
        let mut sorted = values.clone();
        sorted.sort_unstable();

        // split the stream across 8 shards round-robin, as concurrent
        // recorders would
        const SHARDS: usize = 8;
        let shards: Vec<Histogram> = (0..SHARDS).map(|_| Histogram::new()).collect();
        for (i, &v) in values.iter().enumerate() {
            shards[i % SHARDS].record(v);
        }

        // merge order A: left to right
        let forward = Histogram::new();
        for s in &shards {
            forward.merge_from(s);
        }
        // merge order B: right to left
        let backward = Histogram::new();
        for s in shards.iter().rev() {
            backward.merge_from(s);
        }
        // merge order C: pairwise tree
        let tree = Histogram::new();
        for pair in shards.chunks(2) {
            let partial = Histogram::new();
            for s in pair {
                partial.merge_from(s);
            }
            tree.merge_from(&partial);
        }

        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 0.999, 1.0] {
            let f = forward.quantile(q);
            assert_eq!(f, backward.quantile(q), "merge order changed q={q} (seed {seed})");
            assert_eq!(f, tree.quantile(q), "tree merge changed q={q} (seed {seed})");
            assert_within_one_bucket(
                f,
                reference_quantile(&sorted, q),
                &format!("seed {seed} q={q}"),
            );
        }
        let s = forward.summary();
        assert_eq!(s.count, values.len() as u64);
        assert_eq!(s.min, sorted[0]);
        assert_eq!(s.max, *sorted.last().unwrap());
        assert_eq!(s.sum, values.iter().sum::<u64>());
    }
}

/// Zero-overhead property: once a registry's handles exist (warm-up),
/// the hot-path operations — counter inc/add, gauge set/add, histogram
/// record, the `disabled()` fast check, and cached-handle re-lookup via
/// snapshot-free reads — allocate NOTHING.
#[test]
fn registry_hot_path_does_not_allocate_after_warmup() {
    let reg = Registry::new();
    // warm-up: create every handle and touch every path once (first
    // record faults in nothing — the histogram's buckets are inline —
    // but keep warm-up and measurement strictly separated anyway)
    let counter = reg.counter("hot.counter");
    let gauge = reg.gauge("hot.gauge");
    let hist = reg.histogram("hot.hist_us");
    counter.inc();
    gauge.set(1);
    hist.record(17);
    let _ = telemetry::disabled();

    let before = allocs_on_this_thread();
    for i in 0..10_000u64 {
        counter.inc();
        counter.add(3);
        gauge.add(1);
        gauge.set(i as i64);
        hist.record(i * 37 % 1_000_000);
        // the kill-switch check is part of the hot path; its value is
        // irrelevant here (the flag test may flip it concurrently)
        std::hint::black_box(telemetry::disabled());
    }
    let after = allocs_on_this_thread();
    assert_eq!(
        after - before,
        0,
        "hot-path metric operations allocated {} times",
        after - before
    );

    // reading values back is also allocation-free
    let before = allocs_on_this_thread();
    let total = counter.get() + hist.count() + gauge.get().unsigned_abs();
    let after = allocs_on_this_thread();
    assert!(total > 0);
    assert_eq!(after - before, 0, "metric reads allocated");
}

/// The global enable switch and trace sampling, exercised serially in
/// ONE test so no parallel test in this binary observes the flags mid
/// flip. Disabled telemetry must mint no trace ids and record no
/// events; sampling must keep a deterministic subset of jobs.
#[test]
fn enabled_flag_and_sampling_gate_the_trace_plane() {
    // -- disabled: no ids, no events --
    telemetry::set_enabled(false);
    assert!(telemetry::disabled());
    assert_eq!(telemetry::trace::ensure_trace("flag-off-job"), None);
    telemetry::trace::event_for("flag-off-job", "propose");
    assert!(telemetry::trace::for_job("flag-off-job").is_empty());

    // -- re-enabled: the same job now mints and records --
    telemetry::set_enabled(true);
    assert!(telemetry::enabled());
    let id = telemetry::trace::ensure_trace("flag-off-job").expect("enabled mints an id");
    assert_eq!(telemetry::trace::trace_id("flag-off-job"), Some(id));
    telemetry::trace::event_for("flag-off-job", "dispatch");
    let events = telemetry::trace::for_job("flag-off-job");
    // ensure_trace records "propose" at mint, then our explicit dispatch
    let phases: Vec<&str> = events.iter().map(|e| e.phase).collect();
    assert_eq!(phases, vec!["propose", "dispatch"]);
    telemetry::trace::forget("flag-off-job");

    // -- sampling: with 1-in-2 sampling over many names, some jobs get
    // ids and some don't, deterministically by name hash --
    telemetry::trace::set_sampling(2);
    let mut sampled = 0usize;
    let mut skipped = 0usize;
    for i in 0..64 {
        let name = format!("sample-probe-{i}");
        match telemetry::trace::ensure_trace(&name) {
            Some(_) => sampled += 1,
            None => skipped += 1,
        }
        // same name → same verdict (the decision is a pure name hash)
        let again = telemetry::trace::ensure_trace(&name);
        assert_eq!(again.is_some(), telemetry::trace::trace_id(&name).is_some());
        telemetry::trace::forget(&name);
    }
    telemetry::trace::set_sampling(1);
    assert!(sampled > 0, "1-in-2 sampling kept nothing out of 64 jobs");
    assert!(skipped > 0, "1-in-2 sampling skipped nothing out of 64 jobs");

    // -- back at 1-in-1, every job is traced again --
    assert!(telemetry::trace::ensure_trace("sample-probe-final").is_some());
    telemetry::trace::forget("sample-probe-final");
}

/// Satellite: trace-ring overflow must never be silent. Overfilling the
/// bounded ring increments the dropped counter, and the counter is
/// exported as `telemetry.trace_dropped` in every service snapshot (and
/// therefore in the `amt stats` table).
#[test]
fn trace_ring_overflow_is_counted_and_exported() {
    use amt::api::AmtService;
    use amt::platform::PlatformConfig;
    use amt::telemetry::trace::RING_CAP;

    let job = "overflow-job";
    let id = telemetry::trace::ensure_trace(job).expect("telemetry defaults on");
    let dropped_before = telemetry::trace::dropped();
    // Overfill: RING_CAP events land, then every further event evicts
    // (and counts) one. Other tests in this binary may also be writing
    // events concurrently, so assert a lower bound, not equality.
    const EXCESS: usize = 128;
    for _ in 0..RING_CAP + EXCESS {
        telemetry::trace::event(id, job, "dispatch");
    }
    let newly_dropped = telemetry::trace::dropped() - dropped_before;
    assert!(
        newly_dropped >= EXCESS as u64,
        "overfilling by {EXCESS} dropped only {newly_dropped} events"
    );

    // The counter rides every service telemetry snapshot by name.
    let service = AmtService::new(PlatformConfig::noiseless());
    let snapshot = service.telemetry_snapshot();
    let exported = snapshot
        .counter("telemetry.trace_dropped")
        .expect("telemetry.trace_dropped missing from snapshot");
    assert!(
        exported >= newly_dropped,
        "snapshot exported {exported} < {newly_dropped} observed drops"
    );
    assert!(
        snapshot.counter("telemetry.trace_minted").is_some(),
        "telemetry.trace_minted missing from snapshot"
    );
    // ... and therefore in the rendered `amt stats` table.
    assert!(
        snapshot.render_table().contains("telemetry.trace_dropped"),
        "stats table must list telemetry.trace_dropped"
    );

    // Leave the global ring tidy for other tests in this binary.
    telemetry::trace::drain();
    telemetry::trace::forget(job);
}
