//! Load & chaos observatory integration tests (DESIGN.md §16).
//!
//! - determinism property: two `Runner`s built from the same workload
//!   JSON + seed expand to the bit-identical operation sequence (op
//!   kinds, tenants, full requests, chaos firing points), so every chaos
//!   soak is replayable;
//! - `load_smoke`: the CI-named mixed workload on the loopback
//!   distributed plane with a worker kill, a late join and a graceful
//!   drain — every invariant observer must pass and the per-op `load.*`
//!   histograms must be nonzero;
//! - a durable local-plane workload whose chaos track closes and reopens
//!   the leader mid-run.

use std::collections::BTreeSet;

use amt::load::{ChaosAction, OpKind, PlannedOp, Runner, Workload};

#[test]
fn same_seed_plans_are_identical() {
    let spec = Workload::canned_mixed("det-load", 1234, 1);
    let text = spec.to_json().to_string();

    let a = Runner::from_json_str(&text).expect("valid workload");
    let b = Runner::from_json_str(&text).expect("valid workload");
    assert_eq!(
        a.plan(),
        b.plan(),
        "same workload JSON + seed must expand to the identical op sequence"
    );

    // The JSON codec round-trips the spec exactly, plan included.
    let reparsed = Workload::from_json_str(&text).expect("roundtrip parse");
    assert_eq!(spec, reparsed, "workload JSON roundtrip must be lossless");
    assert_eq!(&spec.plan(), a.plan());

    // Chaos firing points are part of the deterministic sequence.
    let chaos_positions = |r: &Runner| -> Vec<(usize, usize)> {
        r.plan()
            .ops
            .iter()
            .enumerate()
            .filter_map(|(i, op)| match op {
                PlannedOp::Chaos { index } => Some((i, *index)),
                _ => None,
            })
            .collect()
    };
    assert_eq!(chaos_positions(&a), chaos_positions(&b));
    assert_eq!(chaos_positions(&a).len(), spec.chaos.len(), "every chaos entry fires once");

    // A different seed reshuffles the stream (op kinds and/or configs).
    let other = Workload::canned_mixed("det-load", 1235, 1);
    let c = Runner::new(other).expect("valid workload");
    assert_ne!(a.plan(), c.plan(), "different seeds must yield different plans");
}

#[test]
fn workload_validation_rejects_bad_specs() {
    // Chaos beyond the schedule.
    let mut w = Workload::canned_mixed("bad-load", 1, 1);
    w.chaos[0].at_op = w.total_ops();
    assert!(w.validate().is_err(), "chaos past the last op must be rejected");

    // Kill of a worker index outside the fleet.
    let mut w = Workload::canned_mixed("bad-load", 1, 1);
    w.chaos[0].action = ChaosAction::KillWorker(w.workers);
    assert!(w.validate().is_err(), "kill of an out-of-range worker must be rejected");

    // Fleet chaos requires the distributed plane.
    let mut w = Workload::canned_mixed("bad-load", 1, 1);
    w.plane = amt::load::Plane::Local;
    assert!(w.validate().is_err(), "kill/join/drain on the local plane must be rejected");

    // Leader reopen requires durability.
    let mut w = Workload::canned_reopen("bad-load", 1);
    w.durable = false;
    assert!(w.validate().is_err(), "reopen_leader without durable must be rejected");

    // A mix with no create kind can never make progress.
    let mut w = Workload::canned_mixed("bad-load", 1, 1);
    w.mix.retain(|m| !m.op.is_create());
    assert!(w.validate().is_err(), "mix without creates must be rejected");

    // Unknown fields in the codec fail loudly.
    assert!(Workload::from_json_str("{\"name\":\"x\",\"plane\":\"orbital\"}").is_err());
    assert!(Workload::from_json_str("not json").is_err());
}

/// The CI `load_smoke` step: a ~10s mixed workload (every create flavor
/// plus describe/list/stop/wait polling) on the loopback distributed
/// plane with one worker kill, one late join and one graceful drain. All
/// invariant observers must pass and the SLO histograms must be nonzero.
#[test]
fn load_smoke_mixed_distributed_kill_drain() {
    let workload = Workload::canned_mixed("smoke-load", 42, 1);
    let runner = Runner::new(workload).expect("canned workload is valid");

    // The canned plan really is "mixed": at least 3 distinct op kinds and
    // at least 2 chaos events, as the acceptance criteria demand.
    let kinds: BTreeSet<&'static str> = runner
        .plan()
        .ops
        .iter()
        .filter_map(|op| match op {
            PlannedOp::Create(c) => Some(c.kind.as_str()),
            PlannedOp::Describe { .. } => Some("describe"),
            PlannedOp::List => Some("list"),
            PlannedOp::Stop { .. } => Some("stop"),
            PlannedOp::Wait { .. } => Some("wait"),
            _ => None,
        })
        .collect();
    assert!(kinds.len() >= 3, "canned mix degenerated to {kinds:?}");
    assert!(runner.plan().chaos_count() >= 2, "canned plan must fire >= 2 chaos events");

    let report = runner.run().expect("run completes");
    assert!(
        report.all_passed(),
        "invariant observers failed:\n{}",
        report.observers.render()
    );
    assert!(report.jobs_created > 0, "no jobs created");
    assert!(report.evaluations > 0, "no evaluations recorded");
    assert_eq!(report.chaos_fired as usize, runner.plan().chaos_count());
    assert!(report.pool.joins >= 4, "3 initial workers + 1 late join expected");
    assert!(report.pool.drains >= 1, "graceful drain must complete");

    for name in ["load.create_us", "load.describe_us", "load.wait_us"] {
        let h = report
            .snapshot
            .histogram(name)
            .unwrap_or_else(|| panic!("{name} missing from merged snapshot"));
        assert!(h.count > 0, "{name} recorded no operations");
    }
}

/// Warm-start chains survive a create targeting a registry-objective
/// parent: the plan only selects eligible parents, and the runner
/// barriers on the parent before resolving the transfer set.
#[test]
fn warm_start_chains_resolve_against_finished_parents() {
    let mut workload = Workload::canned_mixed("warm-load", 7, 1);
    // Bias the mix hard toward warm starts so the chain is exercised.
    for m in &mut workload.mix {
        m.weight = match m.op {
            OpKind::CreateRandom => 3,
            OpKind::CreateWarmStart => 6,
            OpKind::Describe => 2,
            _ => 1,
        };
    }
    workload.phases.truncate(1);
    workload.phases[0].ops = 24;
    workload.chaos.clear();
    let runner = Runner::new(workload).expect("valid workload");
    let has_warm = runner
        .plan()
        .creates()
        .iter()
        .any(|c| !c.request.warm_start_parents.is_empty());
    assert!(has_warm, "biased mix produced no warm-start creates");
    let report = runner.run().expect("run completes");
    assert!(
        report.all_passed(),
        "invariant observers failed:\n{}",
        report.observers.render()
    );
}

/// Durable local-plane workload with a leader close+reopen mid-run: the
/// run continues against the reopened service and the observers (version
/// monotonicity across the reopen, replay attribution, conservation)
/// still pass.
#[test]
fn reopen_leader_mid_run_keeps_invariants() {
    let workload = Workload::canned_reopen("reopen-load", 11);
    let runner = Runner::new(workload).expect("valid workload");
    let report = runner.run().expect("run completes");
    assert!(
        report.all_passed(),
        "invariant observers failed:\n{}",
        report.observers.render()
    );
    assert_eq!(report.chaos_fired, 1, "the reopen must fire exactly once");
    assert!(report.jobs_created > 0);
}
