//! Distributed-plane integration tests (DESIGN.md §11) through the
//! public `AmtService` surface, all over the loopback transport — the
//! full encode → frame → decode wire path, deterministically in one
//! process.
//!
//! The centerpiece is the acceptance property: a 64-job spike through
//! the `RemoteWorkerPool` finishes with **bit-identical** per-job
//! trajectories, final store contents (values *and* versions) and
//! metric series to the same spike on the in-process scheduler. The
//! worker-kill test then exercises the lease/requeue machinery: jobs on
//! a killed worker are reset and replayed on the survivor, and the
//! final state still matches an uninterrupted run.

use std::sync::Arc;
use std::time::{Duration, Instant};

use amt::api::AmtService;
use amt::config::TuningJobRequest;
use amt::coordinator::TuningJobOutcome;
use amt::distributed::leader::RemoteConfig;
use amt::distributed::transport::{LoopbackFault, Transport};
use amt::distributed::worker::spawn_loopback_worker;
use amt::platform::PlatformConfig;
use amt::workflow::ExecutionStatus;

struct WorkerSet {
    faults: Vec<Arc<LoopbackFault>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

fn spawn_workers(n: usize, tag: &str) -> (Vec<Box<dyn Transport>>, WorkerSet) {
    let mut transports = Vec::new();
    let mut faults = Vec::new();
    let mut handles = Vec::new();
    for i in 0..n {
        let (t, fault, h) = spawn_loopback_worker(&format!("{tag}-{i}"));
        transports.push(t);
        faults.push(fault);
        handles.push(h);
    }
    (transports, WorkerSet { faults, handles })
}

impl WorkerSet {
    fn join(self) {
        for h in self.handles {
            let _ = h.join();
        }
    }
}

/// The spike both planes run: a mix of objectives and strategies, a
/// weighted tenant, and (second phase) warm-started BO children.
fn spike_requests() -> (Vec<TuningJobRequest>, Vec<TuningJobRequest>) {
    let mut parents = Vec::new();
    for i in 0..4u64 {
        parents.push(TuningJobRequest {
            name: format!("dist-parent-{i}"),
            objective: "branin".into(),
            strategy: "random".into(),
            max_training_jobs: 5,
            max_parallel_jobs: 2,
            seed: 100 + i,
            ..Default::default()
        });
    }
    let mut children = Vec::new();
    for i in 0..58u64 {
        children.push(TuningJobRequest {
            name: format!("dist-{i:02}"),
            objective: if i % 3 == 0 { "xgboost_dm" } else { "branin" }.into(),
            strategy: "random".into(),
            max_training_jobs: 4,
            max_parallel_jobs: 2,
            seed: i,
            tenant_weight: if i % 7 == 0 { 2 } else { 1 },
            ..Default::default()
        });
    }
    // two warm-started BO children: the transfer observations must ship
    // to the worker and seed the strategy exactly as they would locally
    for i in 0..2u64 {
        children.push(TuningJobRequest {
            name: format!("dist-warm-{i}"),
            objective: "branin".into(),
            strategy: "bayesian".into(),
            max_training_jobs: 3,
            max_parallel_jobs: 1,
            seed: 777 + i,
            warm_start_parents: vec![format!("dist-parent-{i}")],
            ..Default::default()
        });
    }
    (parents, children)
}

fn run_spike(svc: &AmtService) -> Vec<(String, TuningJobOutcome)> {
    let (parents, children) = spike_requests();
    let mut outcomes = Vec::new();
    for r in &parents {
        svc.create_tuning_job(r.clone()).unwrap();
    }
    for r in &parents {
        outcomes.push((r.name.clone(), svc.wait(&r.name).unwrap()));
    }
    for r in &children {
        svc.create_tuning_job(r.clone()).unwrap();
    }
    for r in &children {
        outcomes.push((r.name.clone(), svc.wait(&r.name).unwrap()));
    }
    outcomes
}

/// Everything the cross-plane comparison looks at, in bits.
fn outcome_fingerprint(o: &TuningJobOutcome) -> Vec<(String, Option<u64>, u64)> {
    o.evaluations
        .iter()
        .map(|e| {
            (
                e.training_job_name.clone(),
                e.final_value.map(f64::to_bits),
                e.ended_at.to_bits(),
            )
        })
        .collect()
}

fn assert_services_identical(local: &AmtService, remote: &AmtService) {
    assert_eq!(
        local.store().snapshot(),
        remote.store().snapshot(),
        "store contents (values + versions) diverged across planes"
    );
    let streams = local.metrics().list_streams("");
    assert_eq!(streams, remote.metrics().list_streams(""), "stream sets diverged");
    for s in &streams {
        let a: Vec<(u64, u64)> = local
            .metrics()
            .series(s)
            .iter()
            .map(|p| (p.time.to_bits(), p.value.to_bits()))
            .collect();
        let b: Vec<(u64, u64)> = remote
            .metrics()
            .series(s)
            .iter()
            .map(|p| (p.time.to_bits(), p.value.to_bits()))
            .collect();
        assert_eq!(a, b, "metric series '{s}' diverged");
    }
}

/// Acceptance property: a 64-job spike through the loopback
/// `RemoteWorkerPool` is bit-identical to the in-process pool.
#[test]
fn loopback_remote_pool_bit_identical_to_in_process() {
    let local = AmtService::new(PlatformConfig::noiseless());
    let local_outcomes = run_spike(&local);

    let (transports, workers) = spawn_workers(4, "ident");
    let remote = AmtService::with_remote_workers(PlatformConfig::noiseless(), transports);
    let remote_outcomes = run_spike(&remote);

    assert_eq!(local_outcomes.len(), 64);
    for ((name_a, a), (name_b, b)) in local_outcomes.iter().zip(&remote_outcomes) {
        assert_eq!(name_a, name_b);
        assert_eq!(a.status, b.status, "{name_a}: status diverged");
        assert_eq!(
            outcome_fingerprint(a),
            outcome_fingerprint(b),
            "{name_a}: evaluation trajectory diverged"
        );
        assert_eq!(
            a.total_seconds.to_bits(),
            b.total_seconds.to_bits(),
            "{name_a}: virtual timeline diverged"
        );
        match (&a.best, &b.best) {
            (None, None) => {}
            (Some((ca, va)), Some((cb, vb))) => {
                assert_eq!(ca, cb, "{name_a}: best config diverged");
                assert_eq!(va.to_bits(), vb.to_bits(), "{name_a}: best value diverged");
            }
            _ => panic!("{name_a}: best presence diverged"),
        }
    }
    assert_services_identical(&local, &remote);
    assert_eq!(remote.running_jobs(), 0);
    drop(remote);
    workers.join();
}

/// Worker failure: kill one of two workers mid-spike. Its in-flight
/// jobs are reset and replayed on the survivor from their request seeds
/// (requeue-from-checkpoint via the PR 3 recovery machinery), and the
/// final state is bit-identical to a run that was never interrupted.
#[test]
fn killed_worker_jobs_requeue_and_match_uninterrupted_run() {
    let requests: Vec<TuningJobRequest> = (0..6u64)
        .map(|i| TuningJobRequest {
            name: format!("kill-{i}"),
            objective: "branin".into(),
            strategy: "random".into(),
            max_training_jobs: 6,
            max_parallel_jobs: 2,
            seed: 9000 + i,
            ..Default::default()
        })
        .collect();

    // uninterrupted reference on the in-process pool
    let reference = AmtService::new(PlatformConfig::noiseless());
    for r in &requests {
        reference.create_tuning_job(r.clone()).unwrap();
    }
    let mut ref_outcomes = Vec::new();
    for r in &requests {
        ref_outcomes.push(reference.wait(&r.name).unwrap());
    }

    // distributed run with a mid-spike worker kill; small slices make
    // sure jobs take many polls, so the kill lands mid-job. The default
    // lease stays: a killed loopback link errors immediately, so death
    // detection does not depend on lease expiry here.
    let (transports, workers) = spawn_workers(2, "kill");
    let mut svc = AmtService::new(PlatformConfig::noiseless());
    svc.attach_remote_workers(
        transports,
        RemoteConfig { batch_steps: 8, ..RemoteConfig::default() },
    );
    for r in &requests {
        svc.create_tuning_job(r.clone()).unwrap();
    }
    // let the spike get going, then kill worker 0
    let pool = svc.remote_pool().unwrap();
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let total: u64 = requests.iter().filter_map(|r| pool.poll_count(&r.name)).sum();
        if total >= 4 {
            break;
        }
        assert!(Instant::now() < deadline, "spike never started");
        std::thread::yield_now();
    }
    workers.faults[0].kill();

    let mut outcomes = Vec::new();
    for r in &requests {
        outcomes.push(svc.wait(&r.name).unwrap());
    }
    assert_eq!(pool.live_workers(), 1, "killed worker still counted live");

    for (a, b) in ref_outcomes.iter().zip(&outcomes) {
        assert_eq!(b.status, ExecutionStatus::Succeeded, "{} failed", b.name);
        assert_eq!(
            outcome_fingerprint(a),
            outcome_fingerprint(b),
            "{}: trajectory diverged after worker kill",
            a.name
        );
    }
    assert_services_identical(&reference, &svc);
    drop(svc);
    workers.join();
}

/// Remote deltas flow through the leader's durability commit path: a
/// durable service with remote workers survives close/reopen with the
/// exact store the remote jobs produced.
#[test]
fn durable_service_with_remote_workers_recovers_after_close() {
    let dir = std::env::temp_dir().join(format!(
        "amt-dist-dur-{}-{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    let (transports, workers) = spawn_workers(2, "durable");
    let mut svc = AmtService::open(&dir, PlatformConfig::noiseless()).unwrap();
    svc.attach_remote_workers(transports, RemoteConfig::default());
    for i in 0..3u64 {
        svc.create_tuning_job(TuningJobRequest {
            name: format!("dur-remote-{i}"),
            objective: "branin".into(),
            strategy: "random".into(),
            max_training_jobs: 4,
            max_parallel_jobs: 2,
            seed: 40 + i,
            ..Default::default()
        })
        .unwrap();
    }
    for i in 0..3u64 {
        let out = svc.wait(&format!("dur-remote-{i}")).unwrap();
        assert_eq!(out.evaluations.len(), 4);
    }
    let snapshot_before = svc.store().snapshot();
    svc.close().unwrap();
    workers.join();

    let reopened = AmtService::open(&dir, PlatformConfig::noiseless()).unwrap();
    assert!(reopened.recovered_jobs().is_empty(), "terminal jobs must not resume");
    assert_eq!(reopened.store().snapshot(), snapshot_before);
    for i in 0..3u64 {
        let d = reopened.describe_tuning_job(&format!("dur-remote-{i}")).unwrap();
        assert_eq!(d.status, "Completed");
        assert_eq!(d.evaluations, 4);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The per-tenant in-flight quota holds across remote workers too: a
/// quota-1 tenant never occupies two workers at once.
#[test]
fn remote_quota_one_tenant_never_holds_two_workers() {
    let (transports, workers) = spawn_workers(2, "quota");
    let mut svc = AmtService::new(PlatformConfig::noiseless());
    svc.attach_remote_workers(
        transports,
        RemoteConfig { batch_steps: 8, ..RemoteConfig::default() },
    );
    for i in 0..2u64 {
        svc.create_tuning_job(TuningJobRequest {
            name: format!("rq-{i}"),
            objective: "branin".into(),
            strategy: "random".into(),
            max_training_jobs: 30,
            max_parallel_jobs: 2,
            seed: i,
            tenant: "capped".into(),
            max_in_flight: 1,
            ..Default::default()
        })
        .unwrap();
    }
    for i in 0..2u64 {
        let out = svc.wait(&format!("rq-{i}")).unwrap();
        assert_eq!(out.evaluations.len(), 30);
    }
    let pool = svc.remote_pool().unwrap();
    assert_eq!(
        pool.tenant_high_water("capped"),
        1,
        "quota-1 tenant held two remote workers"
    );
    drop(svc);
    workers.join();
}
