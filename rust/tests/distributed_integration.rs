//! Distributed-plane integration tests (DESIGN.md §11) through the
//! public `AmtService` surface, all over the loopback transport — the
//! full encode → frame → decode wire path, deterministically in one
//! process.
//!
//! The centerpiece is the acceptance property: a 64-job spike through
//! the `RemoteWorkerPool` finishes with **bit-identical** per-job
//! trajectories, final store contents (values *and* versions) and
//! metric series to the same spike on the in-process scheduler. The
//! worker-kill test then exercises the lease/requeue machinery: jobs on
//! a killed worker are reset and replayed on the survivor, and the
//! final state still matches an uninterrupted run.

use std::sync::Arc;
use std::time::{Duration, Instant};

use amt::api::AmtService;
use amt::config::TuningJobRequest;
use amt::coordinator::TuningJobOutcome;
use amt::distributed::leader::RemoteConfig;
use amt::distributed::transport::{LoopbackFault, Transport};
use amt::distributed::worker::spawn_loopback_worker;
use amt::platform::PlatformConfig;
use amt::workflow::ExecutionStatus;

struct WorkerSet {
    faults: Vec<Arc<LoopbackFault>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

fn spawn_workers(n: usize, tag: &str) -> (Vec<Box<dyn Transport>>, WorkerSet) {
    let mut transports = Vec::new();
    let mut faults = Vec::new();
    let mut handles = Vec::new();
    for i in 0..n {
        let (t, fault, h) = spawn_loopback_worker(&format!("{tag}-{i}"));
        transports.push(t);
        faults.push(fault);
        handles.push(h);
    }
    (transports, WorkerSet { faults, handles })
}

impl WorkerSet {
    fn join(self) {
        for h in self.handles {
            let _ = h.join();
        }
    }
}

/// The spike both planes run: a mix of objectives and strategies, a
/// weighted tenant, and (second phase) warm-started BO children.
fn spike_requests() -> (Vec<TuningJobRequest>, Vec<TuningJobRequest>) {
    let mut parents = Vec::new();
    for i in 0..4u64 {
        parents.push(TuningJobRequest {
            name: format!("dist-parent-{i}"),
            objective: "branin".into(),
            strategy: "random".into(),
            max_training_jobs: 5,
            max_parallel_jobs: 2,
            seed: 100 + i,
            ..Default::default()
        });
    }
    let mut children = Vec::new();
    for i in 0..58u64 {
        children.push(TuningJobRequest {
            name: format!("dist-{i:02}"),
            objective: if i % 3 == 0 { "xgboost_dm" } else { "branin" }.into(),
            strategy: "random".into(),
            max_training_jobs: 4,
            max_parallel_jobs: 2,
            seed: i,
            tenant_weight: if i % 7 == 0 { 2 } else { 1 },
            ..Default::default()
        });
    }
    // two warm-started BO children: the transfer observations must ship
    // to the worker and seed the strategy exactly as they would locally
    for i in 0..2u64 {
        children.push(TuningJobRequest {
            name: format!("dist-warm-{i}"),
            objective: "branin".into(),
            strategy: "bayesian".into(),
            max_training_jobs: 3,
            max_parallel_jobs: 1,
            seed: 777 + i,
            warm_start_parents: vec![format!("dist-parent-{i}")],
            ..Default::default()
        });
    }
    (parents, children)
}

fn run_spike(svc: &AmtService) -> Vec<(String, TuningJobOutcome)> {
    let (parents, children) = spike_requests();
    let mut outcomes = Vec::new();
    for r in &parents {
        svc.create_tuning_job(r.clone()).unwrap();
    }
    for r in &parents {
        outcomes.push((r.name.clone(), svc.wait(&r.name).unwrap()));
    }
    for r in &children {
        svc.create_tuning_job(r.clone()).unwrap();
    }
    for r in &children {
        outcomes.push((r.name.clone(), svc.wait(&r.name).unwrap()));
    }
    outcomes
}

/// Everything the cross-plane comparison looks at, in bits.
fn outcome_fingerprint(o: &TuningJobOutcome) -> Vec<(String, Option<u64>, u64)> {
    o.evaluations
        .iter()
        .map(|e| {
            (
                e.training_job_name.clone(),
                e.final_value.map(f64::to_bits),
                e.ended_at.to_bits(),
            )
        })
        .collect()
}

fn assert_services_identical(local: &AmtService, remote: &AmtService) {
    assert_eq!(
        local.store().snapshot(),
        remote.store().snapshot(),
        "store contents (values + versions) diverged across planes"
    );
    let streams = local.metrics().list_streams("");
    assert_eq!(streams, remote.metrics().list_streams(""), "stream sets diverged");
    for s in &streams {
        let a: Vec<(u64, u64)> = local
            .metrics()
            .series(s)
            .iter()
            .map(|p| (p.time.to_bits(), p.value.to_bits()))
            .collect();
        let b: Vec<(u64, u64)> = remote
            .metrics()
            .series(s)
            .iter()
            .map(|p| (p.time.to_bits(), p.value.to_bits()))
            .collect();
        assert_eq!(a, b, "metric series '{s}' diverged");
    }
}

/// Acceptance property: a 64-job spike through the loopback
/// `RemoteWorkerPool` is bit-identical to the in-process pool.
#[test]
fn loopback_remote_pool_bit_identical_to_in_process() {
    let local = AmtService::new(PlatformConfig::noiseless());
    let local_outcomes = run_spike(&local);

    let (transports, workers) = spawn_workers(4, "ident");
    let remote = AmtService::with_remote_workers(PlatformConfig::noiseless(), transports);
    let remote_outcomes = run_spike(&remote);

    assert_eq!(local_outcomes.len(), 64);
    for ((name_a, a), (name_b, b)) in local_outcomes.iter().zip(&remote_outcomes) {
        assert_eq!(name_a, name_b);
        assert_eq!(a.status, b.status, "{name_a}: status diverged");
        assert_eq!(
            outcome_fingerprint(a),
            outcome_fingerprint(b),
            "{name_a}: evaluation trajectory diverged"
        );
        assert_eq!(
            a.total_seconds.to_bits(),
            b.total_seconds.to_bits(),
            "{name_a}: virtual timeline diverged"
        );
        match (&a.best, &b.best) {
            (None, None) => {}
            (Some((ca, va)), Some((cb, vb))) => {
                assert_eq!(ca, cb, "{name_a}: best config diverged");
                assert_eq!(va.to_bits(), vb.to_bits(), "{name_a}: best value diverged");
            }
            _ => panic!("{name_a}: best presence diverged"),
        }
    }
    assert_services_identical(&local, &remote);
    assert_eq!(remote.running_jobs(), 0);
    drop(remote);
    workers.join();
}

/// Worker failure: kill one of two workers mid-spike. Its in-flight
/// jobs are reset and replayed on the survivor from their request seeds
/// (requeue-from-checkpoint via the PR 3 recovery machinery), and the
/// final state is bit-identical to a run that was never interrupted.
#[test]
fn killed_worker_jobs_requeue_and_match_uninterrupted_run() {
    let requests: Vec<TuningJobRequest> = (0..6u64)
        .map(|i| TuningJobRequest {
            name: format!("kill-{i}"),
            objective: "branin".into(),
            strategy: "random".into(),
            max_training_jobs: 6,
            max_parallel_jobs: 2,
            seed: 9000 + i,
            ..Default::default()
        })
        .collect();

    // uninterrupted reference on the in-process pool
    let reference = AmtService::new(PlatformConfig::noiseless());
    for r in &requests {
        reference.create_tuning_job(r.clone()).unwrap();
    }
    let mut ref_outcomes = Vec::new();
    for r in &requests {
        ref_outcomes.push(reference.wait(&r.name).unwrap());
    }

    // distributed run with a mid-spike worker kill; small slices make
    // sure jobs take many polls, so the kill lands mid-job. The default
    // lease stays: a killed loopback link errors immediately, so death
    // detection does not depend on lease expiry here.
    let (transports, workers) = spawn_workers(2, "kill");
    let mut svc = AmtService::new(PlatformConfig::noiseless());
    svc.attach_remote_workers(
        transports,
        RemoteConfig { batch_steps: 8, ..RemoteConfig::default() },
    );
    for r in &requests {
        svc.create_tuning_job(r.clone()).unwrap();
    }
    // let the spike get going, then kill worker 0
    let pool = svc.remote_pool().unwrap();
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let total: u64 = requests.iter().filter_map(|r| pool.poll_count(&r.name)).sum();
        if total >= 4 {
            break;
        }
        assert!(Instant::now() < deadline, "spike never started");
        std::thread::yield_now();
    }
    workers.faults[0].kill();

    let mut outcomes = Vec::new();
    for r in &requests {
        outcomes.push(svc.wait(&r.name).unwrap());
    }
    assert_eq!(pool.live_workers(), 1, "killed worker still counted live");

    for (a, b) in ref_outcomes.iter().zip(&outcomes) {
        assert_eq!(b.status, ExecutionStatus::Succeeded, "{} failed", b.name);
        assert_eq!(
            outcome_fingerprint(a),
            outcome_fingerprint(b),
            "{}: trajectory diverged after worker kill",
            a.name
        );
    }
    assert_services_identical(&reference, &svc);
    // release the pool handle before the service: the pool's Drop (the
    // last Arc) is what drains the workers
    drop(pool);
    drop(svc);
    workers.join();
}

/// Remote deltas flow through the leader's durability commit path: a
/// durable service with remote workers survives close/reopen with the
/// exact store the remote jobs produced.
#[test]
fn durable_service_with_remote_workers_recovers_after_close() {
    let dir = std::env::temp_dir().join(format!(
        "amt-dist-dur-{}-{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    let (transports, workers) = spawn_workers(2, "durable");
    let mut svc = AmtService::open(&dir, PlatformConfig::noiseless()).unwrap();
    svc.attach_remote_workers(transports, RemoteConfig::default());
    for i in 0..3u64 {
        svc.create_tuning_job(TuningJobRequest {
            name: format!("dur-remote-{i}"),
            objective: "branin".into(),
            strategy: "random".into(),
            max_training_jobs: 4,
            max_parallel_jobs: 2,
            seed: 40 + i,
            ..Default::default()
        })
        .unwrap();
    }
    for i in 0..3u64 {
        let out = svc.wait(&format!("dur-remote-{i}")).unwrap();
        assert_eq!(out.evaluations.len(), 4);
    }
    let snapshot_before = svc.store().snapshot();
    svc.close().unwrap();
    workers.join();

    let reopened = AmtService::open(&dir, PlatformConfig::noiseless()).unwrap();
    assert!(reopened.recovered_jobs().is_empty(), "terminal jobs must not resume");
    assert_eq!(reopened.store().snapshot(), snapshot_before);
    for i in 0..3u64 {
        let d = reopened.describe_tuning_job(&format!("dur-remote-{i}")).unwrap();
        assert_eq!(d.status, "Completed");
        assert_eq!(d.evaluations, 4);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Durable + distributed end-to-end kill test at scale (ROADMAP): a
/// durable leader drives ~200 remote loopback jobs; one worker is
/// killed mid-run AND the leader is killed (crash-style: WAL committed,
/// no close) and reopened. Both failure legs now ride the O(remaining)
/// resume path — the worker kill requeues from delta-acked snapshots,
/// the reopen fast-resumes from WAL checkpoints — and the recovered
/// final state is bit-identical to an uninterrupted in-memory run.
#[test]
fn durable_leader_with_200_remote_jobs_survives_worker_kill_and_reopen() {
    const JOBS: usize = 200;
    let requests: Vec<TuningJobRequest> = (0..JOBS as u64)
        .map(|i| TuningJobRequest {
            name: format!("soak-{i:03}"),
            objective: "branin".into(),
            strategy: "random".into(),
            max_training_jobs: 2,
            max_parallel_jobs: 2,
            seed: 7000 + i,
            ..Default::default()
        })
        .collect();

    // uninterrupted in-memory reference
    let reference = AmtService::new(PlatformConfig::noiseless());
    for r in &requests {
        reference.create_tuning_job(r.clone()).unwrap();
    }
    for r in &requests {
        reference.wait(&r.name).unwrap();
    }

    let dir = std::env::temp_dir().join(format!(
        "amt-dist-kill-{}-{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    let (snapshot_requeues, scratch_requeues);
    {
        let (transports, workers) = spawn_workers(3, "soak");
        let mut svc = amt::api::AmtService::open_with_options(
            &dir,
            PlatformConfig::noiseless(),
            std::sync::Arc::new(amt::gp::NativeBackend),
            amt::scheduler::SchedulerConfig { workers: 2, batch_steps: 8 },
        )
        .unwrap();
        svc.attach_remote_workers(
            transports,
            RemoteConfig { batch_steps: 8, ..RemoteConfig::default() },
        );
        for r in &requests {
            svc.create_tuning_job(r.clone()).unwrap();
        }
        // let the fleet work, then kill worker 0 mid-spike
        let pool = svc.remote_pool().unwrap();
        let deadline = Instant::now() + Duration::from_secs(120);
        loop {
            let total: u64 =
                requests.iter().filter_map(|r| pool.poll_count(&r.name)).sum();
            if total >= 2 * JOBS as u64 {
                break;
            }
            assert!(Instant::now() < deadline, "spike never got going");
            std::thread::yield_now();
        }
        workers.faults[0].kill();
        // let the repair land and more jobs finish, then kill the leader
        // mid-run: wait for roughly half the fleet to complete
        let deadline = Instant::now() + Duration::from_secs(120);
        loop {
            let done = requests
                .iter()
                .filter(|r| pool.try_outcome(&r.name).is_some())
                .count();
            if done >= JOBS / 2 {
                break;
            }
            assert!(Instant::now() < deadline, "fleet stalled after worker kill");
            std::thread::yield_now();
        }
        snapshot_requeues = pool.snapshot_requeues();
        scratch_requeues = pool.scratch_requeues();
        svc.wal().unwrap().commit().unwrap();
        // leader kill: drop the pool handle then the service (the last
        // Arc's Drop drains the workers); no close(), no snapshot
        drop(pool);
        drop(svc);
        workers.join();
    }

    // reopen: unfinished jobs resume (snapshot fast path wherever a
    // checkpoint was committed) and run to completion on the local plane
    let svc = amt::api::AmtService::open_with_options(
        &dir,
        PlatformConfig::noiseless(),
        std::sync::Arc::new(amt::gp::NativeBackend),
        amt::scheduler::SchedulerConfig { workers: 2, batch_steps: 8 },
    )
    .unwrap();
    for name in svc.recovered_jobs().to_vec() {
        svc.wait(&name).unwrap();
    }
    let stats = svc.recovery_stats();
    assert!(
        stats.fast_resumed >= 1,
        "reopen leg must exercise the snapshot fast path: {stats:?}"
    );
    assert!(
        snapshot_requeues >= 1,
        "worker-kill leg must exercise snapshot requeue \
         (snapshot={snapshot_requeues}, scratch={scratch_requeues})"
    );
    for r in &requests {
        let d = svc.describe_tuning_job(&r.name).unwrap();
        assert_eq!(d.status, "Completed", "{} not completed", r.name);
        assert_eq!(d.evaluations, 2, "{} wrong evaluation count", r.name);
    }
    assert_services_identical(&reference, &svc);
    drop(svc);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Mixed-backend fleet: the leader routes jobs only to workers whose
/// advertised surrogate backend matches the service's, and falls back
/// to the **local** plane when no compatible worker is live.
#[test]
fn mixed_backend_fleet_routes_by_backend_and_falls_back_local() {
    use amt::distributed::worker::spawn_loopback_worker_with_backend;
    use amt::gp::{Dataset, GramScratch, PosteriorState, Score, SurrogateBackend, Theta};
    use amt::linalg::Matrix;

    /// Test double: native math under a different compatibility name.
    struct RenamedBackend;
    impl SurrogateBackend for RenamedBackend {
        fn name(&self) -> &'static str {
            "test-hlo"
        }
        fn gram(&self, x: &Dataset, theta: &Theta) -> Matrix {
            amt::gp::NativeBackend.gram(x, theta)
        }
        fn gram_into(&self, x: &Dataset, theta: &Theta, scratch: &mut GramScratch) {
            amt::gp::NativeBackend.gram_into(x, theta, scratch)
        }
        fn posterior_scores(
            &self,
            post: &PosteriorState,
            x_cand: &Dataset,
            y_best: f64,
        ) -> Vec<Score> {
            amt::gp::NativeBackend.posterior_scores(post, x_cand, y_best)
        }
    }

    // fleet of one native worker + one "test-hlo" worker
    let spawn_fleet = || {
        let (t0, _f0, h0) = spawn_loopback_worker("mixed-native");
        let (t1, _f1, h1) = spawn_loopback_worker_with_backend("mixed-hlo", "test-hlo");
        (vec![t0, t1], vec![h0, h1])
    };

    // a test-hlo service over the mixed fleet: its jobs must land on the
    // test-hlo lane and complete remotely
    let (transports, handles) = spawn_fleet();
    let mut svc = AmtService::with_backend(
        PlatformConfig::noiseless(),
        std::sync::Arc::new(RenamedBackend),
    );
    svc.attach_remote_workers(transports, RemoteConfig::default());
    let req = TuningJobRequest {
        name: "mixed-remote".into(),
        objective: "branin".into(),
        strategy: "random".into(),
        max_training_jobs: 3,
        max_parallel_jobs: 2,
        seed: 31,
        ..Default::default()
    };
    svc.create_tuning_job(req.clone()).unwrap();
    let out = svc.wait("mixed-remote").unwrap();
    assert_eq!(out.status, ExecutionStatus::Succeeded);
    let pool = svc.remote_pool().unwrap();
    assert!(pool.contains("mixed-remote"), "compatible job must run remotely");
    assert_eq!(
        pool.lane_backends(),
        vec![Some("native".to_string()), Some("test-hlo".to_string())]
    );
    drop(pool);
    drop(svc);
    for h in handles {
        let _ = h.join();
    }

    // a test-hlo service over a native-only fleet: no compatible worker
    // ⇒ the job runs on the local plane (and still succeeds)
    let (t0, _f0, h0) = spawn_loopback_worker("native-only");
    let mut svc = AmtService::with_backend(
        PlatformConfig::noiseless(),
        std::sync::Arc::new(RenamedBackend),
    );
    svc.attach_remote_workers(vec![t0], RemoteConfig::default());
    let mut req = req;
    req.name = "mixed-local".into();
    svc.create_tuning_job(req).unwrap();
    let out = svc.wait("mixed-local").unwrap();
    assert_eq!(out.status, ExecutionStatus::Succeeded);
    let pool = svc.remote_pool().unwrap();
    assert!(
        !pool.contains("mixed-local"),
        "incompatible job must fall back to the local plane"
    );
    drop(pool);
    drop(svc);
    let _ = h0.join();
}

/// The per-tenant in-flight quota holds across remote workers too: a
/// quota-1 tenant never occupies two workers at once.
#[test]
fn remote_quota_one_tenant_never_holds_two_workers() {
    let (transports, workers) = spawn_workers(2, "quota");
    let mut svc = AmtService::new(PlatformConfig::noiseless());
    svc.attach_remote_workers(
        transports,
        RemoteConfig { batch_steps: 8, ..RemoteConfig::default() },
    );
    for i in 0..2u64 {
        svc.create_tuning_job(TuningJobRequest {
            name: format!("rq-{i}"),
            objective: "branin".into(),
            strategy: "random".into(),
            max_training_jobs: 30,
            max_parallel_jobs: 2,
            seed: i,
            tenant: "capped".into(),
            max_in_flight: 1,
            ..Default::default()
        })
        .unwrap();
    }
    for i in 0..2u64 {
        let out = svc.wait(&format!("rq-{i}")).unwrap();
        assert_eq!(out.evaluations.len(), 30);
    }
    let pool = svc.remote_pool().unwrap();
    assert_eq!(
        pool.tenant_high_water("capped"),
        1,
        "quota-1 tenant held two remote workers"
    );
    drop(pool);
    drop(svc);
    workers.join();
}
