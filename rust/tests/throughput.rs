//! Throughput-plane integration tests (DESIGN.md §14): coalesced wire
//! slices, protocol-generation interop with pre-coalescing workers, and
//! the cross-driver WAL group commit — all over the loopback transport,
//! deterministically in one process. Also hosts the telemetry-plane wire
//! tests (DESIGN.md §15): trace-id echo across generations and the CI
//! `telemetry_smoke` end-to-end lifecycle check.

use std::sync::Arc;
use std::time::Duration;

use amt::api::AmtService;
use amt::config::TuningJobRequest;
use amt::coordinator::TuningJobOutcome;
use amt::distributed::leader::{RemoteConfig, RemoteJobSpec, RemoteWorkerPool};
use amt::distributed::proto::{Message, PollReply, PROTO_VERSION};
use amt::distributed::transport::{loopback_pair, Transport};
use amt::distributed::worker::spawn_loopback_worker;
use amt::durability::wal::WalRecord;
use amt::durability::DurabilityOptions;
use amt::gp::NativeBackend;
use amt::json::Json;
use amt::metrics::MetricsService;
use amt::platform::PlatformConfig;
use amt::scheduler::SchedulerConfig;
use amt::store::MetadataStore;
use amt::workflow::ExecutionStatus;

fn temp_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "amt-throughput-{tag}-{}-{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ))
}

/// Wire compatibility, old worker → new leader: a scripted generation-1
/// worker (no `proto` awareness beyond advertising 1, slices reported as
/// the legacy `StoreDelta` + `PollResult` pair, no `Batch` decoding)
/// completes a job on a current leader. The leader must never send it a
/// `Batch` frame, must apply the two-message slice through the batched
/// mutation paths (versions recomputed at the leader), and must count
/// two slice messages for the one dispatched poll.
#[test]
fn legacy_two_message_worker_interoperates_with_new_leader() {
    let (leader_end, mut worker_end, _fault) = loopback_pair("legacy");

    let scripted = std::thread::spawn(move || {
        worker_end
            .send(&Message::Hello {
                worker: "legacy".into(),
                backend: "native".into(),
                proto: 1,
            })
            .unwrap();
        loop {
            match worker_end.recv(Duration::from_millis(25)) {
                Err(_) => return, // leader gone: pool dropped
                Ok(Some(Message::Batch { .. })) => {
                    panic!("leader sent Batch to a generation-1 worker")
                }
                Ok(Some(Message::Assign { .. })) => {}
                Ok(Some(Message::PollRequest { job, .. })) => {
                    let records = vec![
                        (
                            1u64,
                            WalRecord::Put {
                                table: "training_jobs".into(),
                                key: format!("{job}-train-0000"),
                                // worker-local version: the leader must
                                // ignore it and derive its own
                                version: 77,
                                value: Json::obj(vec![(
                                    "status",
                                    Json::Str("Completed".into()),
                                )]),
                            },
                        ),
                        (
                            2u64,
                            WalRecord::Emit {
                                stream: format!("{job}/loss"),
                                time: 1.0,
                                value: 0.25,
                            },
                        ),
                    ];
                    worker_end
                        .send(&Message::StoreDelta { job: job.clone(), records })
                        .unwrap();
                    let outcome = TuningJobOutcome {
                        name: job.clone(),
                        evaluations: Vec::new(),
                        best: None,
                        total_seconds: 1.0,
                        total_billable_seconds: 1.0,
                        status: ExecutionStatus::Succeeded,
                        retries: 0,
                    };
                    worker_end
                        .send(&Message::PollResult {
                            job,
                            reply: PollReply::Complete(Box::new(outcome)),
                        })
                        .unwrap();
                }
                Ok(Some(Message::Drain)) => {
                    let _ = worker_end.send(&Message::DrainAck);
                    return;
                }
                Ok(Some(_)) => {}
                Ok(None) => {
                    if worker_end.send(&Message::Heartbeat).is_err() {
                        return;
                    }
                }
            }
        }
    });

    let store = Arc::new(MetadataStore::new());
    let metrics = Arc::new(MetricsService::new());
    let pool = RemoteWorkerPool::new(
        vec![Box::new(leader_end)],
        Arc::clone(&store),
        Arc::clone(&metrics),
        None,
        RemoteConfig::default(),
    );
    assert!(pool.register(RemoteJobSpec {
        request: TuningJobRequest {
            name: "legacy-job".into(),
            objective: "branin".into(),
            strategy: "random".into(),
            max_training_jobs: 1,
            max_parallel_jobs: 1,
            seed: 1,
            ..Default::default()
        },
        platform: PlatformConfig::noiseless(),
        transfer: Vec::new(),
        backend: "native".into(),
    }));
    pool.activate("legacy-job");
    let out = pool.wait("legacy-job").expect("legacy worker never completed the job");
    assert_eq!(out.status, ExecutionStatus::Succeeded);

    // the two-message slice went through the leader's batched apply:
    // value present, version derived by the leader (1, not the
    // worker-local 77), metric point landed
    let (version, value) = store
        .get("training_jobs", "legacy-job-train-0000")
        .expect("delta record missing at the leader");
    assert_eq!(version, 1);
    assert_eq!(value.get("status").and_then(Json::as_str), Some("Completed"));
    assert_eq!(metrics.series("legacy-job/loss").len(), 1);

    // legacy wire cost: exactly two frames for the one dispatched slice
    assert_eq!(pool.polls_dispatched(), 1);
    assert_eq!(pool.slice_messages(), 2);

    drop(pool);
    scripted.join().unwrap();
}

/// Wire compatibility, new worker → scripted leader: a current worker
/// advertises generation ≥ 2, decodes a `Batch` control burst, and
/// reports every slice as exactly ONE `SliceResult` frame — never the
/// legacy `StoreDelta` + `PollResult` pair.
#[test]
fn coalesced_worker_reports_each_slice_as_one_frame() {
    let (mut leader, _fault, handle) = spawn_loopback_worker("coalesce");

    match leader.recv(Duration::from_secs(5)).unwrap() {
        Some(Message::Hello { proto, .. }) => assert!(proto >= PROTO_VERSION),
        other => panic!("expected Hello first, got {other:?}"),
    }

    let request = TuningJobRequest {
        name: "coalesce-job".into(),
        objective: "branin".into(),
        strategy: "random".into(),
        max_training_jobs: 3,
        max_parallel_jobs: 1,
        seed: 7,
        ..Default::default()
    };
    // assign + first poll as one Batch frame: the worker must dispatch
    // the wrapped messages in order
    leader
        .send(&Message::Batch {
            messages: vec![
                Message::Assign {
                    request,
                    platform: PlatformConfig::noiseless(),
                    transfer: Vec::new(),
                    backend: "native".into(),
                    resume: None,
                    cache_seeds: Vec::new(),
                    trace: None,
                },
                Message::PollRequest { job: "coalesce-job".into(), max_steps: 8 },
            ],
        })
        .unwrap();

    let mut polls = 1u64;
    let mut slices = 0u64;
    let mut total_records = 0usize;
    let outcome = loop {
        match leader.recv(Duration::from_secs(10)).unwrap() {
            Some(Message::Heartbeat) => {}
            Some(Message::SliceResult { job, records, reply, trace }) => {
                assert_eq!(job, "coalesce-job");
                // no trace id was assigned, so none may be invented
                assert_eq!(trace, None);
                slices += 1;
                total_records += records.len();
                match reply {
                    PollReply::Pending { .. } => {
                        polls += 1;
                        leader
                            .send(&Message::PollRequest {
                                job: "coalesce-job".into(),
                                max_steps: 8,
                            })
                            .unwrap();
                    }
                    PollReply::Complete(out) => break *out,
                    PollReply::Rejected { reason } => {
                        panic!("worker rejected the job: {reason}")
                    }
                }
            }
            Some(Message::StoreDelta { .. }) | Some(Message::PollResult { .. }) => {
                panic!("current worker sent a legacy two-message slice")
            }
            other => panic!("unexpected worker message: {other:?}"),
        }
    };

    assert_eq!(outcome.status, ExecutionStatus::Succeeded);
    assert_eq!(outcome.evaluations.len(), 3);
    // one frame per slice, and every dispatched poll was answered by
    // exactly one SliceResult
    assert_eq!(slices, polls);
    assert!(total_records > 0, "slices carried no mutation records");

    leader.send(&Message::Drain).unwrap();
    loop {
        match leader.recv(Duration::from_secs(5)).unwrap() {
            Some(Message::DrainAck) => break,
            Some(Message::Heartbeat) | Some(Message::SliceResult { .. }) => {}
            other => panic!("expected DrainAck, got {other:?}"),
        }
    }
    drop(leader);
    handle.join().unwrap();
}

/// Trace-id wire compatibility, gen-3 both sides: an `Assign` carrying a
/// trace id must have that id echoed verbatim on EVERY `SliceResult` the
/// worker reports for the job — the leader keys its `worker_poll` trace
/// phase off the echo, so a dropped or altered id silently kills the
/// lifecycle reconstruction.
#[test]
fn gen3_worker_echoes_trace_id_on_every_slice() {
    let (mut leader, _fault, handle) = spawn_loopback_worker("trace-echo");

    match leader.recv(Duration::from_secs(5)).unwrap() {
        Some(Message::Hello { proto, .. }) => assert!(proto >= PROTO_VERSION),
        other => panic!("expected Hello first, got {other:?}"),
    }

    let request = TuningJobRequest {
        name: "trace-echo-job".into(),
        objective: "branin".into(),
        strategy: "random".into(),
        max_training_jobs: 3,
        max_parallel_jobs: 1,
        seed: 11,
        ..Default::default()
    };
    leader
        .send(&Message::Batch {
            messages: vec![
                Message::Assign {
                    request,
                    platform: PlatformConfig::noiseless(),
                    transfer: Vec::new(),
                    backend: "native".into(),
                    resume: None,
                    cache_seeds: Vec::new(),
                    trace: Some(42),
                },
                Message::PollRequest { job: "trace-echo-job".into(), max_steps: 8 },
            ],
        })
        .unwrap();

    let mut slices = 0u64;
    loop {
        match leader.recv(Duration::from_secs(10)).unwrap() {
            Some(Message::Heartbeat) => {}
            Some(Message::SliceResult { job, reply, trace, .. }) => {
                assert_eq!(job, "trace-echo-job");
                assert_eq!(trace, Some(42), "slice {slices} lost the trace id");
                slices += 1;
                match reply {
                    PollReply::Pending { .. } => leader
                        .send(&Message::PollRequest {
                            job: "trace-echo-job".into(),
                            max_steps: 8,
                        })
                        .unwrap(),
                    PollReply::Complete(out) => {
                        assert_eq!(out.status, ExecutionStatus::Succeeded);
                        break;
                    }
                    PollReply::Rejected { reason } => {
                        panic!("worker rejected the job: {reason}")
                    }
                }
            }
            other => panic!("unexpected worker message: {other:?}"),
        }
    }
    assert!(slices > 0);

    leader.send(&Message::Drain).unwrap();
    loop {
        match leader.recv(Duration::from_secs(5)).unwrap() {
            Some(Message::DrainAck) => break,
            Some(Message::Heartbeat) | Some(Message::SliceResult { .. }) => {}
            other => panic!("expected DrainAck, got {other:?}"),
        }
    }
    drop(leader);
    handle.join().unwrap();
}

/// Trace-id wire compatibility, gen-2 leader → gen-3 worker: a leader
/// that predates trace ids sends `Assign` frames with no `trace` field —
/// which decodes as `None` at the worker (covered at the frame level in
/// `proto::tests`). The worker must complete the job normally and report
/// `trace: None` on every slice rather than minting an id of its own;
/// the reverse direction (gen-1 worker with no trace awareness at all →
/// current leader) is `legacy_two_message_worker_interoperates_with_new_leader`.
#[test]
fn gen2_leader_without_trace_ids_interoperates_with_gen3_worker() {
    let (mut leader, _fault, handle) = spawn_loopback_worker("trace-gen2");

    match leader.recv(Duration::from_secs(5)).unwrap() {
        Some(Message::Hello { proto, .. }) => assert!(proto >= PROTO_VERSION),
        other => panic!("expected Hello first, got {other:?}"),
    }

    let request = TuningJobRequest {
        name: "gen2-job".into(),
        objective: "branin".into(),
        strategy: "random".into(),
        max_training_jobs: 2,
        max_parallel_jobs: 1,
        seed: 13,
        ..Default::default()
    };
    leader
        .send(&Message::Batch {
            messages: vec![
                Message::Assign {
                    request,
                    platform: PlatformConfig::noiseless(),
                    transfer: Vec::new(),
                    backend: "native".into(),
                    resume: None,
                    cache_seeds: Vec::new(),
                    trace: None,
                },
                Message::PollRequest { job: "gen2-job".into(), max_steps: 8 },
            ],
        })
        .unwrap();

    loop {
        match leader.recv(Duration::from_secs(10)).unwrap() {
            Some(Message::Heartbeat) => {}
            Some(Message::SliceResult { job, reply, trace, .. }) => {
                assert_eq!(job, "gen2-job");
                assert_eq!(trace, None, "worker invented a trace id");
                match reply {
                    PollReply::Pending { .. } => leader
                        .send(&Message::PollRequest { job: "gen2-job".into(), max_steps: 8 })
                        .unwrap(),
                    PollReply::Complete(out) => {
                        assert_eq!(out.status, ExecutionStatus::Succeeded);
                        break;
                    }
                    PollReply::Rejected { reason } => {
                        panic!("worker rejected the job: {reason}")
                    }
                }
            }
            other => panic!("unexpected worker message: {other:?}"),
        }
    }

    leader.send(&Message::Drain).unwrap();
    loop {
        match leader.recv(Duration::from_secs(5)).unwrap() {
            Some(Message::DrainAck) => break,
            Some(Message::Heartbeat) | Some(Message::SliceResult { .. }) => {}
            other => panic!("expected DrainAck, got {other:?}"),
        }
    }
    drop(leader);
    handle.join().unwrap();
}

/// End-to-end throughput smoke (the CI `throughput_smoke` step): a
/// durable leader with a group-commit window drives a small loopback
/// fleet. Concurrent lane drivers must share fsyncs (`wal_coalesced >
/// 0`), and the coalesced wire must average well under the legacy two
/// frames per slice.
#[test]
fn throughput_smoke() {
    let dir = temp_dir("smoke");
    let (transports, workers) = {
        let mut transports: Vec<Box<dyn Transport>> = Vec::new();
        let mut handles = Vec::new();
        for i in 0..4 {
            let (t, _fault, h) = spawn_loopback_worker(&format!("smoke-{i}"));
            transports.push(t);
            handles.push(h);
        }
        (transports, handles)
    };
    let mut svc = AmtService::open_with_durability(
        &dir,
        PlatformConfig::noiseless(),
        Arc::new(NativeBackend),
        SchedulerConfig::default(),
        DurabilityOptions {
            auto_checkpoint_bytes: None,
            group_commit_window: Some(Duration::from_millis(3)),
        },
    )
    .unwrap();
    svc.attach_remote_workers(transports, RemoteConfig::default());

    for i in 0..16u64 {
        svc.create_tuning_job(TuningJobRequest {
            name: format!("smoke-{i:02}"),
            objective: "branin".into(),
            strategy: "random".into(),
            max_training_jobs: 4,
            max_parallel_jobs: 2,
            seed: 500 + i,
            ..Default::default()
        })
        .unwrap();
    }
    for i in 0..16u64 {
        let out = svc.wait(&format!("smoke-{i:02}")).unwrap();
        assert_eq!(out.status, ExecutionStatus::Succeeded);
        assert_eq!(out.evaluations.len(), 4);
    }

    let wal = svc.wal().expect("durable service has a WAL");
    assert!(wal.commits() > 0);
    assert!(
        wal.coalesced() > 0,
        "concurrent lane drivers never shared a group commit"
    );

    let pool = svc.remote_pool().expect("remote plane attached");
    let polls = pool.polls_dispatched();
    let msgs = pool.slice_messages();
    // the pool shuts its drivers down when the last Arc drops: release
    // ours before close() so the workers see their links die and exit
    drop(pool);
    assert!(polls > 0);
    // legacy wire cost is exactly 2 frames per slice; the coalesced wire
    // must stay well under that (1 per answered slice, so ≤ polls — a
    // few heartbeat-adjacent races are tolerated)
    assert!(
        msgs <= polls + polls / 2,
        "slice messages not halved: {msgs} messages for {polls} polls"
    );

    // the batched mutation paths really were exercised
    assert!(svc.store().shard_lock_acquisitions() > 0);

    svc.close().unwrap();
    for h in workers {
        let _ = h.join();
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// End-to-end telemetry smoke (the CI `telemetry_smoke` step,
/// DESIGN.md §15): a durable 16-job loopback fleet must leave behind
/// (1) nonzero `wal.commit_us` / `leader.rtt_us` / `store.put_batch_us`
/// latency samples, (2) one complete propose → dispatch → worker_poll →
/// delta_apply → group_commit → outcome trace per job, and (3) a
/// telemetry snapshot whose JSON (the `amt stats --json` surface) parses
/// back through the crate's own parser.
#[test]
fn telemetry_smoke() {
    let dir = temp_dir("telemetry");
    let (transports, workers) = {
        let mut transports: Vec<Box<dyn Transport>> = Vec::new();
        let mut handles = Vec::new();
        for i in 0..4 {
            let (t, _fault, h) = spawn_loopback_worker(&format!("tsmoke-{i}"));
            transports.push(t);
            handles.push(h);
        }
        (transports, handles)
    };
    let mut svc = AmtService::open_with_durability(
        &dir,
        PlatformConfig::noiseless(),
        Arc::new(NativeBackend),
        SchedulerConfig::default(),
        DurabilityOptions {
            auto_checkpoint_bytes: None,
            group_commit_window: Some(Duration::from_millis(3)),
        },
    )
    .unwrap();
    svc.attach_remote_workers(transports, RemoteConfig::default());

    for i in 0..16u64 {
        svc.create_tuning_job(TuningJobRequest {
            name: format!("tsmoke-{i:02}"),
            objective: "branin".into(),
            strategy: "random".into(),
            max_training_jobs: 4,
            max_parallel_jobs: 2,
            seed: 900 + i,
            ..Default::default()
        })
        .unwrap();
    }
    for i in 0..16u64 {
        let out = svc.wait(&format!("tsmoke-{i:02}")).unwrap();
        assert_eq!(out.status, ExecutionStatus::Succeeded);
    }

    // (2) every job reconstructs a full slice lifecycle from the ring
    const PHASES: [&str; 6] =
        ["propose", "dispatch", "worker_poll", "delta_apply", "group_commit", "outcome"];
    for i in 0..16u64 {
        let name = format!("tsmoke-{i:02}");
        let events = svc.traces_for(&name);
        assert!(!events.is_empty(), "no trace events for {name}");
        let id = events[0].trace_id;
        assert!(events.iter().all(|e| e.trace_id == id), "mixed trace ids for {name}");
        assert!(
            events.windows(2).all(|w| w[0].t_us <= w[1].t_us),
            "trace timestamps for {name} not monotone"
        );
        let phases: Vec<&str> = events.iter().map(|e| e.phase).collect();
        for phase in PHASES {
            assert!(phases.contains(&phase), "{name} missing phase {phase}: {phases:?}");
        }
        assert_eq!(phases.first(), Some(&"propose"), "{name} did not start at propose");
        assert_eq!(phases.last(), Some(&"outcome"), "{name} did not end at outcome");
    }

    // (1) the latency histograms saw real samples on every layer
    let snap = svc.telemetry_snapshot();
    let hist_count =
        |name: &str| snap.histogram(name).map_or(0, |h| h.count);
    assert!(hist_count("wal.commit_us") > 0, "no WAL commit latency samples");
    assert!(hist_count("leader.rtt_us") > 0, "no wire round-trip samples");
    assert!(hist_count("store.put_batch_us") > 0, "no store batch samples");
    assert!(snap.counter("wal.commits").unwrap_or(0) > 0);
    assert!(snap.counter("leader.polls_dispatched").unwrap_or(0) > 0);
    assert!(snap.counter("leader.slice_messages").unwrap_or(0) > 0);
    assert!(snap.counter("store.writes").unwrap_or(0) > 0);
    assert_eq!(snap.counter("leader.joins"), Some(4));

    // (3) the JSON export round-trips through the crate parser
    let text = snap.to_json().to_string();
    let parsed = amt::json::parse(&text).expect("stats JSON must parse");
    let wal_hist = parsed.get("wal.commit_us").expect("wal.commit_us in JSON");
    assert!(wal_hist.get("count").and_then(Json::as_i64).unwrap_or(0) > 0);
    for field in ["p50_us", "p99_us", "p999_us", "min_us", "max_us", "mean_us"] {
        assert!(wal_hist.get(field).is_some(), "histogram JSON missing {field}");
    }
    assert_eq!(
        parsed.get("leader.joins").and_then(Json::as_i64),
        Some(4),
        "counter JSON mismatch"
    );

    svc.close().unwrap();
    for h in workers {
        let _ = h.join();
    }
    let _ = std::fs::remove_dir_all(&dir);
}
