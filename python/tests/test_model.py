"""L2 correctness: GP graphs against a from-scratch numpy GP, and the MLP
training graphs against basic learning behaviour.

These are the same checks the Rust integration tests perform against the
compiled artifacts; here they validate the *math* at the JAX level.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

D = 8


def _theta(rng, d=D):
    # mild, well-conditioned hyperparameters
    log_amp = np.log(rng.uniform(0.5, 2.0))
    log_noise = np.log(rng.uniform(1e-3, 1e-1))
    log_ls = np.log(rng.uniform(0.2, 1.0, size=d))
    log_a = np.log(rng.uniform(0.7, 1.4, size=d))
    log_b = np.log(rng.uniform(0.7, 1.4, size=d))
    return jnp.asarray(
        np.concatenate([[log_amp, log_noise], log_ls, log_a, log_b]), jnp.float32
    )


def _numpy_kernel(x, theta):
    th = np.asarray(theta, np.float64)
    d = x.shape[1]
    amp, noise = np.exp(th[0]), np.exp(th[1])
    ls = np.exp(th[2 : 2 + d])
    wa = np.exp(th[2 + d : 2 + 2 * d])
    wb = np.exp(th[2 + 2 * d : 2 + 3 * d])
    k = np.asarray(
        ref.matern52_cross_ref(
            jnp.asarray(x, jnp.float32),
            jnp.asarray(x, jnp.float32),
            jnp.asarray(wa, jnp.float32),
            jnp.asarray(wb, jnp.float32),
            jnp.asarray(1.0 / ls, jnp.float32),
            jnp.float32(amp),
        ),
        np.float64,
    )
    return k, amp, noise


def test_kernel_matrix_masking_identity_rows():
    rng = np.random.default_rng(0)
    n, live = 32, 20
    x = rng.uniform(size=(n, D)).astype(np.float32)
    mask = np.zeros(n, np.float32)
    mask[:live] = 1.0
    theta = _theta(rng)
    k = np.asarray(model.kernel_matrix(jnp.asarray(x), jnp.asarray(mask), theta))
    # dead rows/cols are exactly identity
    for i in range(live, n):
        np.testing.assert_allclose(k[i], np.eye(n)[i], atol=1e-7)
        np.testing.assert_allclose(k[:, i], np.eye(n)[i], atol=1e-7)
    # live block equals the raw kernel + (noise + jitter) I
    kr, _, noise = _numpy_kernel(x[:live], theta)
    np.testing.assert_allclose(
        k[:live, :live], kr + (noise + model.JITTER) * np.eye(live), rtol=1e-4, atol=1e-5
    )


def test_kernel_matrix_is_choleskyable_under_padding():
    rng = np.random.default_rng(1)
    for live in [1, 5, 16]:
        n = 16
        x = rng.uniform(size=(n, D)).astype(np.float32)
        mask = np.zeros(n, np.float32)
        mask[:live] = 1.0
        k = np.asarray(
            model.kernel_matrix(jnp.asarray(x), jnp.asarray(mask), _theta(rng)),
            np.float64,
        )
        np.linalg.cholesky(k)  # raises if not PD


def test_posterior_ei_matches_numpy_gp():
    rng = np.random.default_rng(2)
    n, m, live = 32, 256, 24
    x = rng.uniform(size=(n, D)).astype(np.float32)
    x[live:] = 0.0
    y = rng.normal(size=n).astype(np.float32)
    y[live:] = 0.0
    mask = np.zeros(n, np.float32)
    mask[:live] = 1.0
    theta = _theta(rng)
    xc = rng.uniform(size=(m, D)).astype(np.float32)

    k = np.asarray(model.kernel_matrix(jnp.asarray(x), jnp.asarray(mask), theta), np.float64)
    k_inv = np.linalg.inv(k)
    alpha = k_inv @ y
    y_best = float(y[:live].min())

    ei, mu, var = model.posterior_ei(
        jnp.asarray(x),
        jnp.asarray(mask),
        theta,
        jnp.asarray(k_inv, jnp.float32),
        jnp.asarray(alpha, jnp.float32),
        jnp.asarray(xc),
        jnp.asarray([y_best], jnp.float32),
    )

    # independent numpy computation on the live block only
    th = np.asarray(theta, np.float64)
    amp = np.exp(th[0])
    ls = np.exp(th[2 : 2 + D])
    wa = np.exp(th[2 + D : 2 + 2 * D])
    wb = np.exp(th[2 + 2 * D : 2 + 3 * D])
    kx = np.asarray(
        ref.matern52_cross_ref(
            jnp.asarray(xc),
            jnp.asarray(x[:live]),
            jnp.asarray(wa, jnp.float32),
            jnp.asarray(wb, jnp.float32),
            jnp.asarray(1.0 / ls, jnp.float32),
            jnp.float32(amp),
        ),
        np.float64,
    )
    k_live = k[:live, :live]
    k_live_inv = np.linalg.inv(k_live)
    mu_np = kx @ (k_live_inv @ y[:live])
    var_np = amp - np.sum((kx @ k_live_inv) * kx, axis=1)
    np.testing.assert_allclose(np.asarray(mu), mu_np, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(var), np.maximum(var_np, 1e-12), rtol=2e-3, atol=1e-4)

    sigma = np.sqrt(np.maximum(var_np, 1e-12))
    z = (y_best - mu_np) / sigma
    from scipy.stats import norm as _norm  # noqa: PLC0415

    ei_np = sigma * (z * _norm.cdf(z) + _norm.pdf(z))
    np.testing.assert_allclose(np.asarray(ei), ei_np, rtol=2e-3, atol=1e-4)


def test_ei_zero_when_far_worse():
    """EI at a candidate with mu >> y_best and tiny sigma must be ~0."""
    rng = np.random.default_rng(5)
    n, live = 16, 16
    x = rng.uniform(size=(n, D)).astype(np.float32)
    y = (10.0 + rng.normal(size=n)).astype(np.float32)
    mask = np.ones(n, np.float32)
    theta = _theta(rng)
    k = np.asarray(model.kernel_matrix(jnp.asarray(x), jnp.asarray(mask), theta), np.float64)
    k_inv = np.linalg.inv(k)
    alpha = k_inv @ y
    # candidates at the training points: tiny sigma, mu ≈ 10 >> y_best = -10
    xc = np.tile(x, (16, 1))[:256]
    ei, _, _ = model.posterior_ei(
        jnp.asarray(x), jnp.asarray(mask), theta,
        jnp.asarray(k_inv, jnp.float32), jnp.asarray(alpha, jnp.float32),
        jnp.asarray(xc, jnp.float32), jnp.asarray([-10.0], jnp.float32),
    )
    assert float(np.max(np.asarray(ei))) < 1e-3


def test_ei_positive_under_uncertainty():
    rng = np.random.default_rng(6)
    n = 16
    x = (0.5 * np.ones((n, D))).astype(np.float32)
    y = rng.normal(size=n).astype(np.float32)
    mask = np.ones(n, np.float32)
    theta = _theta(rng)
    k = np.asarray(model.kernel_matrix(jnp.asarray(x), jnp.asarray(mask), theta), np.float64)
    k_inv = np.linalg.inv(k)
    alpha = k_inv @ y
    # far-away candidates: posterior ≈ prior, sigma large, EI > 0
    xc = np.zeros((256, D), np.float32)
    xc[:, 0] = np.linspace(0.0, 1.0, 256)
    ei, _, var = model.posterior_ei(
        jnp.asarray(x), jnp.asarray(mask), theta,
        jnp.asarray(k_inv, jnp.float32), jnp.asarray(alpha, jnp.float32),
        jnp.asarray(xc), jnp.asarray([float(y.min())], jnp.float32),
    )
    assert float(np.asarray(ei).max()) > 1e-4
    assert float(np.asarray(var).min()) >= 0.0


# --------------------------- MLP graphs -----------------------------------


def _toy_data(rng, rows, f=10, w=None):
    x = rng.normal(size=(rows, f)).astype(np.float32)
    if w is None:
        w = rng.normal(size=f)
    y = (x @ w + 0.1 * rng.normal(size=rows) > 0).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(y), w


def _init(rng, f, h):
    return (
        jnp.asarray(rng.normal(size=(f, h)) * 0.3, jnp.float32),
        jnp.zeros(h, jnp.float32),
        jnp.asarray(rng.normal(size=h) * 0.3, jnp.float32),
        jnp.zeros(1, jnp.float32),
    )


@pytest.mark.parametrize("h", [8, 32])
def test_mlp_training_reduces_loss(h):
    rng = np.random.default_rng(42)
    x, y, w = _toy_data(rng, 512)
    xv, yv, _ = _toy_data(rng, 256, w=w)  # same labeling function as train
    w1, b1, w2, b2 = _init(rng, 10, h)
    lr = jnp.asarray([0.03], jnp.float32)
    l2 = jnp.asarray([1e-4], jnp.float32)
    loss0, acc0 = model.mlp_eval(w1, b1, w2, b2, xv, yv)
    for _ in range(40):
        w1, b1, w2, b2, _tr = model.mlp_train_epoch(
            w1, b1, w2, b2, x, y, lr, l2, num_batches=8
        )
    loss1, acc1 = model.mlp_eval(w1, b1, w2, b2, xv, yv)
    assert float(loss1[0]) < float(loss0[0])
    assert float(acc1[0]) > 0.8, f"accuracy {float(acc1[0])} too low"


def test_mlp_l2_shrinks_weights():
    rng = np.random.default_rng(1)
    x, y, _ = _toy_data(rng, 512)
    params_lo = _init(rng, 10, 8)
    params_hi = tuple(jnp.array(p) for p in params_lo)
    lr = jnp.asarray([0.05], jnp.float32)
    for _ in range(10):
        *params_lo, _ = model.mlp_train_epoch(*params_lo, x, y, lr, jnp.asarray([0.0], jnp.float32), num_batches=8)
        *params_hi, _ = model.mlp_train_epoch(*params_hi, x, y, lr, jnp.asarray([0.05], jnp.float32), num_batches=8)
    n_lo = float(jnp.sum(params_lo[0] ** 2))
    n_hi = float(jnp.sum(params_hi[0] ** 2))
    assert n_hi < n_lo
