"""L1 correctness: Pallas Matérn-5/2 kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes (incl. non-square, block-boundary and tiny sizes)
and parameter magnitudes; every case asserts allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matern, ref

jax.config.update("jax_platform_name", "cpu")


def _rand(rng, *shape):
    return jnp.asarray(rng.uniform(0.0, 1.0, size=shape), jnp.float32)


def _params(rng, d, warped=True):
    if warped:
        wa = jnp.asarray(rng.uniform(0.3, 3.0, size=d), jnp.float32)
        wb = jnp.asarray(rng.uniform(0.3, 3.0, size=d), jnp.float32)
    else:
        wa = jnp.ones(d, jnp.float32)
        wb = jnp.ones(d, jnp.float32)
    ils = jnp.asarray(1.0 / rng.uniform(0.05, 2.0, size=d), jnp.float32)
    amp = jnp.float32(rng.uniform(0.1, 3.0))
    return wa, wb, ils, amp


def _check(xa, xb, wa, wb, ils, amp, atol=2e-5):
    got = matern.matern52_cross(xa, xb, wa, wb, ils, amp)
    want = ref.matern52_cross_ref(xa, xb, wa, wb, ils, amp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=atol)


@pytest.mark.parametrize("m,n", [(16, 16), (256, 64), (128, 128), (256, 512), (512, 512)])
def test_cross_matches_ref_bucket_shapes(m, n):
    rng = np.random.default_rng(m * 1000 + n)
    d = 8
    xa, xb = _rand(rng, m, d), _rand(rng, n, d)
    _check(xa, xb, *_params(rng, d))


@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([1, 2, 3, 5, 8, 16, 48, 130]),
    n=st.sampled_from([1, 2, 4, 7, 16, 96, 129]),
    d=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
    warped=st.booleans(),
)
def test_cross_matches_ref_hypothesis(m, n, d, seed, warped):
    rng = np.random.default_rng(seed)
    xa, xb = _rand(rng, m, d), _rand(rng, n, d)
    _check(xa, xb, *_params(rng, d, warped))


def test_gram_is_symmetric_psd():
    rng = np.random.default_rng(7)
    x = _rand(rng, 64, 8)
    wa, wb, ils, amp = _params(rng, 8)
    k = np.asarray(matern.matern52_gram(x, wa, wb, ils, amp), np.float64)
    np.testing.assert_allclose(k, k.T, atol=1e-6)
    evals = np.linalg.eigvalsh(k + 1e-6 * np.eye(64))
    assert evals.min() > 0, f"Gram not PSD: min eigenvalue {evals.min()}"


def test_diagonal_equals_amplitude():
    rng = np.random.default_rng(11)
    x = _rand(rng, 32, 4)
    wa, wb, ils, amp = _params(rng, 4)
    k = np.asarray(matern.matern52_gram(x, wa, wb, ils, amp))
    np.testing.assert_allclose(np.diag(k), np.full(32, float(amp)), rtol=1e-5)


def test_identity_warp_reduces_to_plain_matern():
    """With a=b=1 the Kumaraswamy CDF is (numerically) the identity."""
    rng = np.random.default_rng(13)
    d = 6
    xa, xb = _rand(rng, 32, d), _rand(rng, 16, d)
    ones = jnp.ones(d, jnp.float32)
    ils = jnp.asarray(1.0 / rng.uniform(0.1, 1.0, size=d), jnp.float32)
    amp = jnp.float32(1.5)
    got = np.asarray(matern.matern52_cross(xa, xb, ones, ones, ils, amp))
    # plain Matérn on raw (clipped) inputs
    want = np.asarray(ref.matern52_cross_ref(xa, xb, ones, ones, ils, amp))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=2e-5)


def test_kernel_decays_with_distance():
    d = 2
    wa = jnp.ones(d, jnp.float32)
    ils = jnp.full(d, 5.0, jnp.float32)
    amp = jnp.float32(1.0)
    base = jnp.zeros((1, d), jnp.float32)
    pts = jnp.asarray([[0.1, 0.1], [0.4, 0.4], [0.9, 0.9]], jnp.float32)
    k = np.asarray(matern.matern52_cross(base, pts, wa, wa, ils, amp))[0]
    assert k[0] > k[1] > k[2] > 0.0


def test_float64_inputs_are_cast():
    rng = np.random.default_rng(3)
    xa = jnp.asarray(rng.uniform(size=(16, 4)))  # f32 by default in jax, but be explicit
    wa, wb, ils, amp = _params(rng, 4)
    out = matern.matern52_cross(xa.astype(jnp.float32), xa.astype(jnp.float32), wa, wb, ils, amp)
    assert out.dtype == jnp.float32


def test_kumaraswamy_monotone_and_bounded():
    x = jnp.linspace(0.0, 1.0, 101)
    for a, b in [(0.5, 0.5), (1.0, 1.0), (2.0, 3.0), (0.3, 4.0)]:
        w = np.asarray(ref.kumaraswamy_ref(x, a, b))
        assert (np.diff(w) >= -1e-7).all()
        assert w.min() >= 0.0 and w.max() <= 1.0
