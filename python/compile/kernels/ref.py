"""Pure-jnp oracle for the Pallas Matérn-5/2 kernel (correctness reference).

Deliberately written in the most direct O(M*N*D) broadcast style, with no
blocking and no matmul expansion, so that any algebraic shortcut taken by
the Pallas kernel is validated against first-principles math.
"""

from __future__ import annotations

import jax.numpy as jnp

_EPS = 1e-6
_SQRT5 = 2.2360679774997896


def kumaraswamy_ref(x, a, b):
    """Kumaraswamy CDF, same clipping as the kernel."""
    xc = jnp.clip(x, _EPS, 1.0 - _EPS)
    return 1.0 - (1.0 - xc**a) ** b


def matern52_cross_ref(xa, xb, warp_a, warp_b, inv_ls, amp):
    """Reference pairwise warped Matérn-5/2 covariance.

    Shapes match ``matern.matern52_cross``: xa (M, D), xb (N, D), parameter
    vectors (D,), scalar amp; returns (M, N).
    """
    wa = kumaraswamy_ref(xa, warp_a[None, :], warp_b[None, :]) * inv_ls[None, :]
    wb = kumaraswamy_ref(xb, warp_a[None, :], warp_b[None, :]) * inv_ls[None, :]
    diff = wa[:, None, :] - wb[None, :, :]  # (M, N, D)
    r2 = jnp.sum(diff * diff, axis=-1)
    r = jnp.sqrt(jnp.maximum(r2, 0.0))
    return amp * (1.0 + _SQRT5 * r + (5.0 / 3.0) * r2) * jnp.exp(-_SQRT5 * r)


def matern52_gram_ref(x, warp_a, warp_b, inv_ls, amp):
    return matern52_cross_ref(x, x, warp_a, warp_b, inv_ls, amp)
