"""L1 Pallas kernel: blocked pairwise Matérn-5/2 ARD covariance with fused
Kumaraswamy input warping.

This is the compute hot spot of the AMT Bayesian-optimization surrogate: it
is evaluated for every slice-sampling likelihood query (kernel Gram matrix)
and for every acquisition batch (cross covariance between candidates and the
training set). The kernel is written so that the pairwise term runs as a
matmul (MXU-friendly on a real TPU) via the expansion

    r2[i, j] = |wa_i|^2 + |wb_j|^2 - 2 <wa_i, wb_j>

where ``wa = kumaraswamy(x_a) / lengthscale`` is computed inside the block
(fused warping — the warped matrix is never materialized in HBM).

Lowered with ``interpret=True`` so the resulting HLO runs on any PJRT
backend, including the Rust CPU client (real-TPU lowering would emit a
Mosaic custom-call the CPU plugin cannot execute). See DESIGN.md
§Hardware-Adaptation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Numerical guards, shared with the pure-jnp oracle in ref.py.
_EPS = 1e-6
_SQRT5 = 2.2360679774997896


def _kumaraswamy(x, a, b):
    """Kumaraswamy CDF w(x) = 1 - (1 - x^a)^b on [0, 1], clipped for safety."""
    xc = jnp.clip(x, _EPS, 1.0 - _EPS)
    return 1.0 - (1.0 - xc**a) ** b


def _matern52(r2, amp):
    """Matérn-5/2 from squared distance; amp is the signal variance."""
    r = jnp.sqrt(jnp.maximum(r2, 0.0))
    return amp * (1.0 + _SQRT5 * r + (5.0 / 3.0) * r2) * jnp.exp(-_SQRT5 * r)


def _cross_block_kernel(xa_ref, xb_ref, wa_ref, wb_ref, ils_ref, amp_ref, o_ref):
    """One (bm, bn) output tile: warp both input tiles, scale by inverse
    lengthscales, take pairwise squared distances via the matmul expansion,
    and apply the Matérn-5/2 form."""
    a = wa_ref[...]  # (1, D) warp a
    b = wb_ref[...]  # (1, D) warp b
    ils = ils_ref[...]  # (1, D) inverse lengthscales

    wa = _kumaraswamy(xa_ref[...], a, b) * ils  # (bm, D)
    wb = _kumaraswamy(xb_ref[...], a, b) * ils  # (bn, D)

    na = jnp.sum(wa * wa, axis=1, keepdims=True)  # (bm, 1)
    nb = jnp.sum(wb * wb, axis=1, keepdims=True)  # (bn, 1)
    # MXU path: the only O(bm*bn*D) term is this dot.
    cross = jnp.dot(wa, wb.T, preferred_element_type=jnp.float32)
    r2 = na + nb.T - 2.0 * cross
    o_ref[...] = _matern52(r2, amp_ref[0, 0])


def _pick_block(n: int, target: int = 128) -> int:
    """Largest divisor of ``n`` that is <= target (shapes here are powers of
    two, so this returns min(n, target) in practice)."""
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def matern52_cross(xa, xb, warp_a, warp_b, inv_ls, amp, *, block_m=128, block_n=128):
    """Pairwise warped Matérn-5/2 covariance K[i, j] = k(xa_i, xb_j).

    Args:
      xa: (M, D) float32 in [0, 1].
      xb: (N, D) float32 in [0, 1].
      warp_a, warp_b: (D,) Kumaraswamy shape parameters (positive).
      inv_ls: (D,) inverse ARD lengthscales (positive).
      amp: () signal variance.

    Returns:
      (M, N) float32 covariance matrix.
    """
    m, d = xa.shape
    n, _ = xb.shape
    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    grid = (m // bm, n // bn)

    # Row-vector parameter layout so blocks broadcast cleanly.
    wa_p = warp_a.reshape(1, d).astype(jnp.float32)
    wb_p = warp_b.reshape(1, d).astype(jnp.float32)
    ils_p = inv_ls.reshape(1, d).astype(jnp.float32)
    amp_p = jnp.asarray(amp, jnp.float32).reshape(1, 1)

    return pl.pallas_call(
        _cross_block_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, d), lambda i, j: (0, 0)),
            pl.BlockSpec((1, d), lambda i, j: (0, 0)),
            pl.BlockSpec((1, d), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(
        xa.astype(jnp.float32),
        xb.astype(jnp.float32),
        wa_p,
        wb_p,
        ils_p,
        amp_p,
    )


def matern52_gram(x, warp_a, warp_b, inv_ls, amp, **kw):
    """Gram matrix K(X, X) — same kernel, both operands the train matrix."""
    return matern52_cross(x, x, warp_a, warp_b, inv_ls, amp, **kw)
