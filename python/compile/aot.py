"""AOT compile path: lower every L2 graph to HLO *text* artifacts.

HLO text (NOT ``lowered.compile()`` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which xla_extension 0.5.1 (the version behind the Rust ``xla`` crate)
rejects; the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py and the project README.

Artifacts (written to --out-dir, default ../artifacts):

    kernel_matrix_n{N}.hlo.txt    N in BUCKETS        Gram matrix graph
    posterior_ei_n{N}.hlo.txt     N in BUCKETS        EI / posterior graph
    mlp_train_h{H}.hlo.txt        H in MLP_WIDTHS     one SGD epoch
    mlp_eval_h{H}.hlo.txt         H in MLP_WIDTHS     val loss + accuracy
    manifest.json                                     shape/layout metadata

Run once via ``make artifacts``; Python never executes on the Rust request
path.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Shape contract shared with rust/src/runtime/registry.rs (via manifest.json).
BUCKETS = [16, 32, 64, 128, 256, 512]
ENCODED_DIM = 8  # padded encoded-configuration dimension D
CAND_BATCH = 256  # acquisition candidate batch M
THETA_DIM = 2 + 3 * ENCODED_DIM

MLP_WIDTHS = [8, 32, 128]
MLP_FEATURES = 10
MLP_TRAIN_ROWS = 512
MLP_VAL_ROWS = 256
MLP_NUM_BATCHES = 8


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_kernel_matrix(n: int):
    fn = lambda x, mask, theta: (model.kernel_matrix(x, mask, theta),)
    return jax.jit(fn).lower(
        _spec(n, ENCODED_DIM), _spec(n), _spec(THETA_DIM)
    )


def lower_posterior_ei(n: int):
    fn = lambda *a: model.posterior_ei(*a)
    return jax.jit(fn).lower(
        _spec(n, ENCODED_DIM),
        _spec(n),
        _spec(THETA_DIM),
        _spec(n, n),
        _spec(n),
        _spec(CAND_BATCH, ENCODED_DIM),
        _spec(1),
    )


def lower_mlp_train(h: int):
    fn = functools.partial(model.mlp_train_epoch, num_batches=MLP_NUM_BATCHES)
    return jax.jit(fn).lower(
        _spec(MLP_FEATURES, h),
        _spec(h),
        _spec(h),
        _spec(1),
        _spec(MLP_TRAIN_ROWS, MLP_FEATURES),
        _spec(MLP_TRAIN_ROWS),
        _spec(1),
        _spec(1),
    )


def lower_mlp_eval(h: int):
    return jax.jit(model.mlp_eval).lower(
        _spec(MLP_FEATURES, h),
        _spec(h),
        _spec(h),
        _spec(1),
        _spec(MLP_VAL_ROWS, MLP_FEATURES),
        _spec(MLP_VAL_ROWS),
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated artifact-name prefixes to (re)build",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    only = args.only.split(",") if args.only else None

    jobs = []
    for n in BUCKETS:
        jobs.append((f"kernel_matrix_n{n}", lambda n=n: lower_kernel_matrix(n)))
        jobs.append((f"posterior_ei_n{n}", lambda n=n: lower_posterior_ei(n)))
    for h in MLP_WIDTHS:
        jobs.append((f"mlp_train_h{h}", lambda h=h: lower_mlp_train(h)))
        jobs.append((f"mlp_eval_h{h}", lambda h=h: lower_mlp_eval(h)))

    for name, make in jobs:
        if only and not any(name.startswith(p) for p in only):
            continue
        text = to_hlo_text(make())
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    manifest = {
        "buckets": BUCKETS,
        "encoded_dim": ENCODED_DIM,
        "cand_batch": CAND_BATCH,
        "theta_dim": THETA_DIM,
        "jitter": model.JITTER,
        "mlp": {
            "widths": MLP_WIDTHS,
            "features": MLP_FEATURES,
            "train_rows": MLP_TRAIN_ROWS,
            "val_rows": MLP_VAL_ROWS,
            "num_batches": MLP_NUM_BATCHES,
        },
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print("wrote manifest.json")


if __name__ == "__main__":
    main()
