"""L2: JAX compute graphs for the AMT Bayesian-optimization surrogate and the
end-to-end demo model.

Every public function here is AOT-lowered by ``aot.py`` into HLO text that
the Rust coordinator loads through PJRT. All array shapes are static (one
artifact per train-set-size bucket / model variant); variable-size training
sets are handled with row masks. Scalars travel as shape-(1,) f32 arrays to
keep the Rust literal marshalling uniform.

GP hyperparameter (theta) packing, shared with ``rust/src/gp/theta.rs``::

    theta = [ log_amp, log_noise,
              log_ls[0..D), log_warp_a[0..D), log_warp_b[0..D) ]   # 2 + 3D

The O(N^3) Cholesky lives in Rust (jax>=0.5 lowers linalg.cholesky on CPU to
a LAPACK FFI custom-call that xla_extension 0.5.1 cannot run); these graphs
cover everything else: Gram/cross kernels (Pallas, L1), posterior moments
and expected improvement.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import matern

JITTER = 1e-6
_INV_SQRT2 = 0.7071067811865476
_INV_SQRT_2PI = 0.3989422804014327


def unpack_theta(theta, d):
    """Split a packed theta vector into positive GP hyperparameters."""
    log_amp = theta[0]
    log_noise = theta[1]
    log_ls = theta[2 : 2 + d]
    log_a = theta[2 + d : 2 + 2 * d]
    log_b = theta[2 + 2 * d : 2 + 3 * d]
    return (
        jnp.exp(log_amp),
        jnp.exp(log_noise),
        jnp.exp(log_ls),
        jnp.exp(log_a),
        jnp.exp(log_b),
    )


def kernel_matrix(x, mask, theta):
    """Masked, regularized GP Gram matrix.

    Rows where ``mask == 0`` are replaced with identity rows so that a
    Cholesky of the result ignores padding: the padded subspace contributes
    log-det 0 and decouples from live rows.

    Args:
      x: (N, D) encoded configurations in [0, 1].
      mask: (N,) {0, 1} float; 1 = live training row.
      theta: (2 + 3D,) packed GP hyperparameters.

    Returns:
      (N, N) matrix ``(m m^T) * K + diag((1 - m) + m*(noise + jitter))``.
    """
    n, d = x.shape
    amp, noise, ls, wa, wb = unpack_theta(theta, d)
    k = matern.matern52_gram(x, wa, wb, 1.0 / ls, amp)
    mm = mask[:, None] * mask[None, :]
    diag = (1.0 - mask) + mask * (noise + JITTER)
    return mm * k + jnp.diag(diag)


def _norm_pdf(z):
    return _INV_SQRT_2PI * jnp.exp(-0.5 * z * z)


def _erf(x):
    """Abramowitz–Stegun 7.1.26 polynomial erf (|err| < 1.5e-7).

    Deliberately NOT ``jax.lax.erf``: that lowers to a first-class ``erf``
    HLO opcode which xla_extension 0.5.1's text parser predates ("Unknown
    opcode: erf"), so the artifact would silently fall back to the native
    path. This is also bit-comparable to ``rust/src/gp/mod.rs::erf``, which
    uses the same polynomial.
    """
    sign = jnp.sign(x)
    ax = jnp.abs(x)
    t = 1.0 / (1.0 + 0.3275911 * ax)
    poly = t * (
        0.254829592
        + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429)))
    )
    return sign * (1.0 - poly * jnp.exp(-ax * ax))


def _norm_cdf(z):
    return 0.5 * (1.0 + _erf(z * _INV_SQRT2))


def posterior_ei(x_train, mask, theta, k_inv, alpha, x_cand, y_best):
    """Posterior moments and expected improvement at a candidate batch.

    The Rust side factorizes K = L L^T once per theta sample and passes in
    ``k_inv = K^{-1}`` and ``alpha = K^{-1} y``; this graph then scores an
    arbitrary candidate batch:

        mu    = Kx alpha
        var   = amp - rowsum((Kx K^{-1}) * Kx)
        EI    = sigma * (z Phi(z) + phi(z)),  z = (y_best - mu) / sigma

    Args:
      x_train: (N, D); mask: (N,); theta: (2 + 3D,)
      k_inv: (N, N); alpha: (N,)
      x_cand: (M, D); y_best: (1,) incumbent (minimization).

    Returns:
      (ei, mu, var): three (M,) vectors.
    """
    _, d = x_train.shape
    amp, _, ls, wa, wb = unpack_theta(theta, d)
    kx = matern.matern52_cross(x_cand, x_train, wa, wb, 1.0 / ls, amp)
    kx = kx * mask[None, :]  # padded columns contribute nothing
    mu = kx @ alpha
    var = amp - jnp.sum((kx @ k_inv) * kx, axis=1)
    var = jnp.maximum(var, 1e-12)
    sigma = jnp.sqrt(var)
    z = (y_best[0] - mu) / sigma
    ei = sigma * (z * _norm_cdf(z) + _norm_pdf(z))
    return ei, mu, var


# ---------------------------------------------------------------------------
# End-to-end demo model: a small MLP binary classifier trained entirely
# through AOT artifacts (the "real workload" of examples/end_to_end.rs).
# One train/eval artifact pair per hidden width H (a categorical HP).
# ---------------------------------------------------------------------------


def _mlp_logits(w1, b1, w2, b2, x):
    h = jnp.tanh(x @ w1 + b1[None, :])
    return h @ w2 + b2[0]


def _mlp_loss(params, x, y, l2):
    w1, b1, w2, b2 = params
    logits = _mlp_logits(w1, b1, w2, b2, x)
    # numerically stable logistic loss
    nll = jnp.mean(jnp.maximum(logits, 0.0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    reg = l2 * (jnp.sum(w1 * w1) + jnp.sum(w2 * w2))
    return nll + reg


def mlp_train_epoch(w1, b1, w2, b2, x, y, lr, l2, num_batches: int):
    """One epoch of minibatch SGD; returns updated params and mean loss.

    x: (B, F), y: (B,) with B divisible by num_batches; lr, l2: (1,).
    """
    b = x.shape[0]
    mb = b // num_batches
    grad_fn = jax.value_and_grad(_mlp_loss)

    def body(i, carry):
        params, loss_acc = carry
        xb = jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)
        yb = jax.lax.dynamic_slice_in_dim(y, i * mb, mb, axis=0)
        loss, grads = grad_fn(params, xb, yb, l2[0])
        params = tuple(p - lr[0] * g for p, g in zip(params, grads))
        return params, loss_acc + loss

    (w1, b1, w2, b2), loss_sum = jax.lax.fori_loop(
        0, num_batches, body, ((w1, b1, w2, b2), jnp.float32(0.0))
    )
    return w1, b1, w2, b2, (loss_sum / num_batches).reshape(1)


def mlp_eval(w1, b1, w2, b2, x, y):
    """Validation loss and accuracy; returns two (1,) vectors."""
    logits = _mlp_logits(w1, b1, w2, b2, x)
    nll = jnp.mean(
        jnp.maximum(logits, 0.0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
    acc = jnp.mean(((logits > 0.0).astype(jnp.float32) == y).astype(jnp.float32))
    return nll.reshape(1), acc.reshape(1)
