//! Quickstart: tune the Branin function with Bayesian optimization through
//! the full AMT service (API layer → workflow engine → training platform).
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use amt::api::AmtService;
use amt::config::TuningJobRequest;
use amt::platform::PlatformConfig;

fn main() {
    // 1. bring up the managed service (one platform timeline per job)
    let service = AmtService::new(PlatformConfig::default());

    // 2. describe what to tune: workload, strategy, budget, parallelism
    let request = TuningJobRequest {
        name: "quickstart".into(),
        objective: "branin".into(),     // 2-d benchmark, minimum ≈ 0.3979
        strategy: "bayesian".into(),    // GP + EI, slice-sampled GPHPs
        max_training_jobs: 25,          // total evaluations
        max_parallel_jobs: 2,           // asynchronous parallelism (§4.4)
        early_stopping: "off".into(),
        seed: 42,
        ..Default::default()
    };

    // 3. CreateHyperParameterTuningJob + wait for the workflow
    let name = service.create_tuning_job(request).expect("create");
    let outcome = service.wait(&name).expect("wait");

    // 4. inspect results
    println!(
        "finished: {:?}; {} evaluations in {:.0} simulated seconds",
        outcome.status,
        outcome.evaluations.len(),
        outcome.total_seconds
    );
    let (config, best) = outcome.best.clone().expect("at least one evaluation");
    println!("best branin value: {best:.5} (optimum 0.39789) at:");
    for (k, v) in &config {
        println!("  {k} = {v:?}");
    }

    println!("\nbest-so-far trajectory:");
    for (t, v) in outcome.best_over_time(true) {
        println!("  t = {t:>7.0}s   best = {v:.5}");
    }

    // the Describe API reads the same state from the metadata store
    let summary = service.describe_tuning_job(&name).expect("describe");
    println!("\nDescribeHyperParameterTuningJob: status = {}", summary.status);
    assert!(best < 2.0, "BO should land near a Branin basin");
}
