//! Figure 2 reproduction: validation score of an SVM as a function of its
//! capacity parameter C over [1e-9, 1e9] (log axis) — the motivation for
//! log scaling (§5.1): 99% of the *linear* volume of this range sits in
//! [1e7, 1e9], so linear-scale search underexplores small C.
//!
//! ```bash
//! cargo run --release --example fig2_log_scaling
//! ```

use amt::harness::print_table;
use amt::objectives::SvmCapacity;
use amt::space::{to_unit, Scaling};

fn main() {
    // dense sweep over log10 C ∈ [-9, 9]
    let mut rows = Vec::new();
    let mut series = Vec::new();
    for i in 0..=36 {
        let log_c = -9.0 + i as f64 * 0.5;
        let c = 10f64.powf(log_c);
        let acc = SvmCapacity::accuracy(c);
        series.push((log_c, acc));
        if i % 2 == 0 {
            rows.push(vec![format!("1e{log_c:.0}"), format!("{acc:.4}")]);
        }
    }
    print_table("Fig 2: SVM validation score vs capacity C", &["C", "val score"], &rows);

    // ASCII rendering of the curve (x = log10 C, y = accuracy)
    println!("\nvalidation score (y: 0.40–1.00) vs log10(C) (x: -9..9):");
    let (lo, hi) = (0.40, 1.00);
    for level in (0..=12).rev() {
        let y = lo + (hi - lo) * level as f64 / 12.0;
        let mut line = format!("{y:5.2} |");
        for &(_, acc) in &series {
            line.push(if (acc - y).abs() < (hi - lo) / 24.0 { '*' } else { ' ' });
        }
        println!("{line}");
    }
    println!("      +{}", "-".repeat(series.len()));
    println!("       -9{}9", " ".repeat(series.len() - 4));

    // the quantitative claim behind log scaling (§5.1)
    let frac_linear_above_1e7 =
        1.0 - to_unit(1e7, 1e-9, 1e9, Scaling::Linear);
    println!(
        "\nlinear-volume share of C in [1e7, 1e9]: {:.2}% (paper: 99%)",
        frac_linear_above_1e7 * 100.0
    );
    let peak = series
        .iter()
        .cloned()
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    println!(
        "peak validation score {:.4} at C = 1e{:.1} — far outside that region",
        peak.1, peak.0
    );
}
