//! End-to-end validation driver: every layer of the stack composes on a
//! real workload.
//!
//! * **L1/L2**: the GP surrogate runs on the AOT HLO artifacts (Pallas
//!   Matérn kernel + posterior/EI graphs) through PJRT — `HloBackend`;
//! * **model under tuning**: a *genuinely trained* MLP classifier whose
//!   every SGD epoch and evaluation is itself an HLO artifact execution
//!   (`mlp_train_h*` / `mlp_eval_h*`) — a "custom algorithm" in SageMaker
//!   terms;
//! * **L3**: the full AMT service — Create API → workflow engine →
//!   training-platform simulator → median-rule early stopping →
//!   metadata store.
//!
//! Requires `make artifacts`. Reported: tuned validation loss/accuracy,
//! best configuration, loss curve of the best configuration, early-stopping
//! savings. Recorded in EXPERIMENTS.md §e2e.
//!
//! ```bash
//! cargo run --release --example end_to_end [evals]
//! ```

use std::sync::Arc;

use amt::api::AmtService;
use amt::config::TuningJobRequest;
use amt::harness::print_table;
use amt::platform::PlatformConfig;
use amt::runtime::mlp::MlpObjective;
use amt::runtime::{HloBackend, HloRuntime};

fn main() {
    let evals: u32 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);

    let runtime = HloRuntime::open_default()
        .expect("artifacts missing — run `make artifacts` first");
    println!(
        "runtime up: buckets {:?}, D = {}, MLP widths {:?}",
        runtime.manifest.buckets, runtime.manifest.encoded_dim, runtime.manifest.mlp_widths
    );

    // GP surrogate on the HLO path; the MLP workload trains through HLO too
    let backend = Arc::new(HloBackend::new(Arc::clone(&runtime)));
    let service = AmtService::with_backend(PlatformConfig::default(), backend);
    let objective = Arc::new(MlpObjective::new(Arc::clone(&runtime), 1234, 12));

    let request = TuningJobRequest {
        name: "e2e-mlp".into(),
        objective: "mlp_real".into(),
        strategy: "bayesian".into(),
        max_training_jobs: evals,
        max_parallel_jobs: 2,
        early_stopping: "median".into(),
        seed: 7,
        ..Default::default()
    };
    println!(
        "tuning the HLO-trained MLP: {} evaluations, BO + median-rule early stopping\n",
        evals
    );
    let t0 = std::time::Instant::now();
    let name = service
        .create_custom_tuning_job(request, objective.clone())
        .expect("create");
    let outcome = service.wait(&name).expect("wait");
    let wall = t0.elapsed().as_secs_f64();

    // ---- report ----
    let mut rows = Vec::new();
    for e in &outcome.evaluations {
        rows.push(vec![
            e.training_job_name.clone(),
            format!("{:?}", e.status),
            e.final_value.map(|v| format!("{v:.4}")).unwrap_or_else(|| "-".into()),
            if e.stopped_early { "yes".into() } else { "".into() },
            format!("{}", e.curve.len()),
        ]);
    }
    print_table(
        "end-to-end: evaluations",
        &["training job", "status", "val loss", "stopped", "epochs"],
        &rows,
    );

    let (best_config, best_loss) = outcome.best.clone().expect("has best");
    let accuracy = objective.final_accuracy(&best_config, 7);
    let stopped = outcome.evaluations.iter().filter(|e| e.stopped_early).count();
    let epochs_run: usize = outcome.evaluations.iter().map(|e| e.curve.len()).sum();
    let epochs_full = outcome.evaluations.len() * 12;

    println!("\nbest configuration:");
    for (k, v) in &best_config {
        println!("  {k} = {v:?}");
    }
    println!("best validation loss: {best_loss:.4}");
    println!("validation accuracy of the tuned model: {accuracy:.4}");
    println!(
        "early stopping: {stopped}/{} evaluations stopped; {epochs_run}/{epochs_full} epochs run",
        outcome.evaluations.len()
    );
    println!(
        "artifact executions: {} (GP + MLP, all via PJRT)",
        runtime.executions.load(std::sync::atomic::Ordering::Relaxed)
    );
    println!("real wall-clock: {wall:.1}s; simulated platform time: {:.0}s", outcome.total_seconds);

    println!("\nloss curve of the best configuration (retrained):");
    let curve = amt::objectives::Objective::curve(objective.as_ref(), &best_config, 7);
    for (i, v) in curve.iter().enumerate() {
        let bar = "#".repeat(((v / curve[0]).min(1.2) * 40.0) as usize);
        println!("  epoch {:>2}  {v:.4}  {bar}", i + 1);
    }

    assert!(accuracy > 0.8, "tuned MLP should classify well: acc = {accuracy}");
    assert!(best_loss < 0.45, "tuned val loss should be decent: {best_loss}");
    println!("\nEND-TO-END OK: all three layers composed on a real trained model.");
}
