//! Multi-objective extension demo (§8 Conclusion future work): search the
//! Pareto frontier of validation error vs training cost for the image-
//! classifier workload — "configurations that are optimal along several
//! criteria", via ParEGO-style scalarization over the standard AMT BO
//! engine.
//!
//! ```bash
//! cargo run --release --example multi_objective [evals]
//! ```

use std::sync::Arc;

use amt::gp::NativeBackend;
use amt::harness::print_table;
use amt::multiobjective::{hypervolume_2d, MultiObservation, ParEgoOptimizer};
use amt::objectives::{Objective, SvmCapacity};
use amt::strategies::BoConfig;

fn main() {
    let evals: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(30);
    // SVM capacity: accuracy improves with C while training cost grows —
    // a genuine accuracy-vs-cost frontier (Fig 2's landscape, §5.1)
    let workload = SvmCapacity;
    let space = workload.space();

    let mut opt = ParEgoOptimizer::new(
        space,
        Arc::new(NativeBackend),
        BoConfig::default(),
        2,
        11,
    );

    // objectives (both minimized): classification error and training cost
    let mut history: Vec<MultiObservation> = Vec::new();
    for i in 0..evals {
        let config = opt.next_config(&history, &[]);
        let c = config.get("C").unwrap().as_f64().unwrap();
        let error = 1.0 - SvmCapacity::accuracy(c);
        let cost_hours =
            workload.epoch_seconds(&config) * workload.max_epochs() as f64 / 3600.0;
        history.push(MultiObservation { config, values: vec![error, cost_hours] });
        let _ = i;
    }

    let front = opt.front(&history);
    let mut rows: Vec<Vec<String>> = front
        .iter()
        .map(|o| {
            vec![
                format!("{:.4}", 1.0 - o.values[0]),
                format!("{:.2}h", o.values[1]),
                format!("{:.2e}", o.config.get("C").unwrap().as_f64().unwrap()),
            ]
        })
        .collect();
    rows.sort();
    print_table(
        "Pareto front: accuracy vs training cost",
        &["accuracy", "train cost", "C"],
        &rows,
    );

    let pts: Vec<(f64, f64)> = front.iter().map(|o| (o.values[0], o.values[1])).collect();
    let hv = hypervolume_2d(&pts, (1.0, 1.0));
    println!(
        "\n{} evaluations -> {} non-dominated configurations, hypervolume {:.4} (ref (1.0, 1.0h))",
        evals,
        front.len(),
        hv
    );
    assert!(front.len() >= 2, "expected a trade-off frontier");
}
