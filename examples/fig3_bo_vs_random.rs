//! Figure 3 reproduction: BO vs random search tuning XGBoost's `alpha` /
//! `lambda` regularizers on the direct-marketing workload (§6.1–§6.2).
//!
//! * Left/Middle: the configurations each strategy suggests (scatter in
//!   log-log space, bucketed by score quality);
//! * Right: best model score so far (lower = better) vs number of
//!   evaluations, mean ± std over replicated seeds.
//!
//! ```bash
//! cargo run --release --example fig3_bo_vs_random [seeds] [evals]
//! ```
//! Paper setting: 50 seeds, 50 evaluations.

use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use amt::config::TuningJobRequest;
use amt::coordinator::{stopping_by_name, TuningJobRunner};
use amt::gp::NativeBackend;
use amt::harness::{mean_std, print_table};
use amt::metrics::MetricsService;
use amt::objectives::by_name;
use amt::platform::{PlatformConfig, TrainingPlatform};
use amt::store::MetadataStore;
use amt::strategies;

fn run_one(strategy: &str, seed: u64, evals: u32) -> Vec<(f64, f64, f64)> {
    // returns (alpha, lambda, final_score) per evaluation, in launch order
    let objective = by_name("xgboost_dm").unwrap();
    let request = TuningJobRequest {
        name: format!("fig3-{strategy}-{seed}"),
        objective: "xgboost_dm".into(),
        strategy: strategy.into(),
        max_training_jobs: evals,
        max_parallel_jobs: 1,
        seed,
        ..Default::default()
    };
    let obj: Arc<dyn amt::objectives::Objective> = objective.into();
    let strat = strategies::by_name(strategy, &obj.space(), Arc::new(NativeBackend), seed)
        .unwrap();
    let runner = TuningJobRunner::new(
        request,
        obj,
        strat,
        stopping_by_name("off").unwrap(),
        TrainingPlatform::new(PlatformConfig::noiseless(), seed),
        Arc::new(MetadataStore::new()),
        Arc::new(MetricsService::new()),
        Arc::new(AtomicBool::new(false)),
    );
    runner
        .run()
        .evaluations
        .iter()
        .map(|e| {
            (
                e.config.get("alpha").unwrap().as_f64().unwrap(),
                e.config.get("lambda").unwrap().as_f64().unwrap(),
                e.final_value.unwrap_or(f64::NAN),
            )
        })
        .collect()
}

fn ascii_scatter(title: &str, points: &[(f64, f64, f64)]) {
    // 44 × 16 grid over log10 alpha, log10 lambda ∈ [-6, 2]
    println!("\n{title}  (x: log10 alpha -6..2, y: log10 lambda -6..2)");
    println!("  marks: # best scores, + middle, . worst");
    let scores: Vec<f64> = points.iter().map(|p| p.2).collect();
    let mut sorted = scores.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q1 = sorted[sorted.len() / 3];
    let q2 = sorted[2 * sorted.len() / 3];
    let mut grid = vec![vec![' '; 45]; 17];
    for &(a, l, s) in points {
        let x = (((a.log10() + 6.0) / 8.0) * 44.0).round().clamp(0.0, 44.0) as usize;
        let y = 16 - (((l.log10() + 6.0) / 8.0) * 16.0).round().clamp(0.0, 16.0) as usize;
        grid[y][x] = if s <= q1 {
            '#'
        } else if s <= q2 {
            '+'
        } else {
            '.'
        };
    }
    for row in grid {
        println!("  |{}|", row.iter().collect::<String>());
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let seeds: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(50);
    let evals: u32 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(50);
    eprintln!("fig3: {seeds} seeds x {evals} evaluations per strategy");

    // ---- Left/Middle panels: suggested configurations of one seed ----
    let random_pts = run_one("random", 7, evals);
    let bo_pts = run_one("bayesian", 7, evals);
    ascii_scatter("Fig 3 left: random-search suggestions", &random_pts);
    ascii_scatter("Fig 3 middle: BO (AMT) suggestions", &bo_pts);

    // ---- Right panel: best-so-far vs evaluations over all seeds ----
    let mut best_random: Vec<Vec<f64>> = Vec::new(); // [seed][eval]
    let mut best_bo: Vec<Vec<f64>> = Vec::new();
    for seed in 0..seeds {
        for (strategy, dest) in
            [("random", &mut best_random), ("bayesian", &mut best_bo)]
        {
            let pts = run_one(strategy, seed, evals);
            let mut best = f64::INFINITY;
            let curve: Vec<f64> = pts
                .iter()
                .map(|p| {
                    best = best.min(p.2);
                    best
                })
                .collect();
            dest.push(curve);
        }
        if (seed + 1) % 10 == 0 {
            eprintln!("  ... {} / {seeds} seeds", seed + 1);
        }
    }

    let mut rows = Vec::new();
    let mut bo_wins = 0;
    let checkpoints: Vec<usize> =
        (0..evals as usize).filter(|i| (i + 1) % 5 == 0 || *i == 0).collect();
    for &i in &checkpoints {
        let r: Vec<f64> = best_random.iter().map(|c| c[i]).collect();
        let b: Vec<f64> = best_bo.iter().map(|c| c[i]).collect();
        let (rm, rs) = mean_std(&r);
        let (bm, bs) = mean_std(&b);
        if bm <= rm {
            bo_wins += 1;
        }
        rows.push(vec![
            format!("{}", i + 1),
            format!("{rm:.4} ± {rs:.4}"),
            format!("{bm:.4} ± {bs:.4}"),
            if bm <= rm { "BO".into() } else { "random".into() },
        ]);
    }
    print_table(
        "Fig 3 right: best score so far (lower is better)",
        &["evals", "random", "BO (AMT)", "leader"],
        &rows,
    );
    println!(
        "\nBO leads at {bo_wins}/{} checkpoints (paper: BO consistently outperforms \
         random search across all numbers of evaluations)",
        rows.len()
    );
}
