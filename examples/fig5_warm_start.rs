//! Figure 5 reproduction: warm starting sequential tuning jobs on the
//! Caltech-256 image-classifier workload (§6.4).
//!
//! Three tuning jobs run through the service, exactly like the paper's case
//! study: (1) from scratch, (2) same algorithm + data warm-started from
//! job 1 ("red dots"), (3) on the *augmented* dataset warm-started from
//! jobs 1+2 ("blue dots"). Validation accuracy should keep improving
//! across phases (paper: 0.33 → 0.47 → 0.52).
//!
//! ```bash
//! cargo run --release --example fig5_warm_start [evals_per_job]
//! ```

use amt::api::AmtService;
use amt::config::TuningJobRequest;
use amt::harness::print_table;
use amt::platform::PlatformConfig;

fn main() {
    let evals: u32 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(12);
    let service = AmtService::new(PlatformConfig::default());

    let phases: [(&str, &str, Vec<String>); 3] = [
        ("phase1-scratch", "caltech_base", vec![]),
        ("phase2-warm", "caltech_rerun", vec!["phase1-scratch".into()]),
        (
            "phase3-augmented",
            "caltech_augmented",
            vec!["phase1-scratch".into(), "phase2-warm".into()],
        ),
    ];

    let mut rows = Vec::new();
    let mut best_by_phase = Vec::new();
    let mut offset = 0.0;
    for (name, objective, parents) in phases {
        let request = TuningJobRequest {
            name: name.into(),
            objective: objective.into(),
            strategy: "bayesian".into(),
            max_training_jobs: evals,
            max_parallel_jobs: 2,
            warm_start_parents: parents.clone(),
            seed: 17,
            ..Default::default()
        };
        let job = service.create_tuning_job(request).expect("create");
        let outcome = service.wait(&job).expect("wait");
        // accuracy over (global) time: phases run back to back
        for (t, v) in outcome.best_over_time(false) {
            rows.push(vec![
                name.to_string(),
                format!("{:.1}h", (offset + t) / 3600.0),
                format!("{v:.4}"),
            ]);
        }
        let best = outcome.best.map(|b| b.1).unwrap_or(0.0);
        best_by_phase.push((name.to_string(), best, parents.len()));
        offset += outcome.total_seconds;
    }

    print_table(
        "Fig 5: best validation accuracy so far over time (3 sequential jobs)",
        &["phase", "time", "best accuracy"],
        &rows,
    );

    let summary: Vec<Vec<String>> = best_by_phase
        .iter()
        .map(|(n, b, p)| vec![n.clone(), format!("{b:.4}"), p.to_string()])
        .collect();
    print_table(
        "Fig 5 summary (paper: 0.33 -> 0.47 -> 0.52)",
        &["phase", "best accuracy", "#parents"],
        &summary,
    );

    assert!(
        best_by_phase[1].1 >= best_by_phase[0].1 - 1e-9,
        "warm-started phase 2 should not regress"
    );
    assert!(
        best_by_phase[2].1 >= best_by_phase[1].1 - 0.02,
        "augmented phase 3 should reach the highest accuracy"
    );
    println!(
        "\nwarm start kept improving: {:.3} -> {:.3} -> {:.3}",
        best_by_phase[0].1, best_by_phase[1].1, best_by_phase[2].1
    );
}
