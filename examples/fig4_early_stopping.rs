//! Figure 4 reproduction: early stopping on the Gdelt linear-learner
//! workload (§6.3). Tuning jobs with a budget of 100 configurations run
//! with and without the median rule, in single-instance and distributed
//! mode; each arm is replicated and the **median best loss so far** is
//! reported over virtual time — the paper's claim being that early
//! stopping reaches a similar loss in less time.
//!
//! ```bash
//! cargo run --release --example fig4_early_stopping [replicates] [configs]
//! ```
//! Paper setting: 10 replicates, 100 configurations.

use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use amt::config::TuningJobRequest;
use amt::coordinator::{stopping_by_name, TuningJobOutcome, TuningJobRunner};
use amt::gp::NativeBackend;
use amt::harness::{print_table, step_interpolate};
use amt::metrics::MetricsService;
use amt::platform::{PlatformConfig, TrainingPlatform};
use amt::store::MetadataStore;
use amt::strategies;

fn run_one(distributed: bool, early: &str, configs: u32, seed: u64) -> TuningJobOutcome {
    let objective_name = if distributed { "gdelt_distributed" } else { "gdelt_single" };
    let obj: Arc<dyn amt::objectives::Objective> =
        amt::objectives::by_name(objective_name).unwrap().into();
    let request = TuningJobRequest {
        name: format!("fig4-{objective_name}-{early}-{seed}"),
        objective: objective_name.into(),
        strategy: "random".into(), // isolate the early-stopping effect
        max_training_jobs: configs,
        max_parallel_jobs: 4,
        early_stopping: early.into(),
        instance_count: if distributed { 8 } else { 1 },
        seed,
        ..Default::default()
    };
    let strat =
        strategies::by_name("random", &obj.space(), Arc::new(NativeBackend), seed).unwrap();
    TuningJobRunner::new(
        request,
        obj,
        strat,
        stopping_by_name(early).unwrap(),
        TrainingPlatform::new(PlatformConfig::default(), seed),
        Arc::new(MetadataStore::new()),
        Arc::new(MetricsService::new()),
        Arc::new(AtomicBool::new(false)),
    )
    .run()
}

fn median_of(mut v: Vec<f64>) -> f64 {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[v.len() / 2]
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let replicates: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(10);
    let configs: u32 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(100);
    eprintln!("fig4: {replicates} replicates x {configs} configurations per arm");

    for &distributed in &[false, true] {
        let mode = if distributed { "distributed (multi-year Gdelt)" } else { "single instance" };
        let mut arms: Vec<(&str, Vec<TuningJobOutcome>)> = Vec::new();
        for early in ["off", "median"] {
            let outs: Vec<TuningJobOutcome> = (0..replicates)
                .map(|seed| run_one(distributed, early, configs, seed))
                .collect();
            arms.push((early, outs));
        }

        // common time grid up to the slowest no-stopping replicate
        let t_max = arms[0]
            .1
            .iter()
            .map(|o| o.total_seconds)
            .fold(0.0f64, f64::max);
        let grid: Vec<f64> = (1..=12).map(|i| t_max * i as f64 / 12.0).collect();

        let mut rows = Vec::new();
        for (gi, &t) in grid.iter().enumerate() {
            let mut cells = vec![format!("{:.1}h", t / 3600.0)];
            for (_, outs) in &arms {
                let vals: Vec<f64> = outs
                    .iter()
                    .map(|o| {
                        step_interpolate(&o.best_over_time(true), &[t], f64::NAN)[0]
                    })
                    .filter(|v| v.is_finite())
                    .collect();
                cells.push(if vals.is_empty() {
                    "-".into()
                } else {
                    format!("{:.4}", median_of(vals))
                });
            }
            let _ = gi;
            rows.push(cells);
        }
        print_table(
            &format!("Fig 4 ({mode}): median best absolute loss vs time"),
            &["time", "no early stopping", "median rule"],
            &rows,
        );

        // headline numbers: final loss and total time per arm
        let mut summary = Vec::new();
        for (early, outs) in &arms {
            let final_losses: Vec<f64> = outs
                .iter()
                .filter_map(|o| o.best.as_ref().map(|b| b.1))
                .collect();
            let times: Vec<f64> = outs.iter().map(|o| o.total_seconds).collect();
            let billable: Vec<f64> =
                outs.iter().map(|o| o.total_billable_seconds).collect();
            let stopped: usize = outs
                .iter()
                .map(|o| o.evaluations.iter().filter(|e| e.stopped_early).count())
                .sum();
            summary.push(vec![
                early.to_string(),
                format!("{:.4}", median_of(final_losses)),
                format!("{:.1}h", median_of(times) / 3600.0),
                format!("{:.1}h", median_of(billable) / 3600.0),
                format!("{:.1}", stopped as f64 / replicates as f64),
            ]);
        }
        print_table(
            &format!("Fig 4 ({mode}): summary"),
            &["early stopping", "final loss", "wall time", "billable", "stopped/job"],
            &summary,
        );
    }
    println!(
        "\npaper's claim: early stopping explores the same number of configurations \
         in less time at similar final loss."
    );
}
