//! §6.5 deployment-scale soak test: the service absorbs a spike of tuning
//! jobs with failure injection while the synchronous APIs stay available.
//!
//! Reported (mirroring the paper's post-launch statistics):
//! * API availability (paper: ≥ 99.99% over 2020) and synchronous-API
//!   latency percentiles (p50/p99) under load;
//! * a spike of concurrent tuning jobs, each running training jobs in
//!   parallel (paper: spikes of many hundreds of tuning jobs, requests with
//!   5 parallel training jobs, individual clusters up to 128 accelerators),
//!   multiplexed over the scheduler's **bounded worker pool** — OS threads
//!   stay ≤ pool size + constant no matter how many jobs spike;
//! * workflow robustness: completed evaluations vs injected failures and
//!   the retries that absorbed them.
//!
//! Emits `BENCH_soak.json` (one entry per spike size; `AMT_BENCH_DIR`
//! overrides the output directory) with p50/p95 API latency in the
//! standard bench schema and jobs/sec, p99 latency and store-write count
//! in the entry params — `scripts/bench.sh` diffs it like the other
//! BENCH files. Each spike also emits the telemetry plane's per-op
//! latency histograms (`scheduler.poll_slice_us`, `store.put_batch_us`,
//! and for distributed spikes `wal.commit_us` / `leader.rtt_us`) with
//! real p50/p99/p999 in the entry params, plus one telemetry-overhead
//! entry comparing instrumented vs `telemetry::set_enabled(false)`
//! throughput (budget: ≤ 3%, DESIGN.md §15).
//!
//! With `--distributed N`, each spike additionally runs through the
//! distributed plane (DESIGN.md §11): N loopback workers hosting the
//! actors behind the full wire protocol, so one binary covers both
//! execution planes.
//!
//! With `--chaos`, the largest spike also runs once over an *elastic*
//! fleet under fault injection (DESIGN.md §13): three loopback workers,
//! one killed mid-run, a fresh one joining mid-run, one drained
//! gracefully — the entry's params report the fleet's `joins` /
//! `drains` / `steals` / requeue / replay counters.
//!
//! ```bash
//! cargo run --release --example scale_soak [tuning_jobs ...] \
//!     [--distributed N] [--chaos]
//! ```

use std::sync::Arc;
use std::time::Instant;

use amt::api::AmtService;
use amt::config::TuningJobRequest;
use amt::distributed::worker::spawn_loopback_worker;
use amt::harness::{print_table, BenchReport, BenchStats};
use amt::platform::PlatformConfig;

/// One spike at `num_jobs` tuning jobs (over `distributed` loopback
/// workers when > 0); returns the report entry fields.
fn run_spike(num_jobs: usize, distributed: usize, report: &mut BenchReport) {
    // hostile platform: real provisioning jitter + failure injection
    let platform = PlatformConfig {
        provisioning_failure_rate: 0.05,
        training_failure_rate: 0.04,
        ..Default::default()
    };
    let mut worker_handles = Vec::new();
    let service = if distributed > 0 {
        let mut transports = Vec::new();
        for i in 0..distributed {
            let (t, _fault, h) = spawn_loopback_worker(&format!("soak-{i}"));
            transports.push(t);
            worker_handles.push(h);
        }
        Arc::new(AmtService::with_remote_workers(platform, transports))
    } else {
        Arc::new(AmtService::new(platform))
    };

    if distributed > 0 {
        eprintln!(
            "spiking {num_jobs} tuning jobs (5 evaluations each, 5 parallel) \
             over {distributed} loopback remote workers..."
        );
    } else {
        eprintln!(
            "spiking {num_jobs} tuning jobs (5 evaluations each, 5 parallel) \
             over {} pool workers...",
            service.worker_count()
        );
    }
    let started = Instant::now();
    let mut created = 0usize;
    // per-call latencies of the synchronous APIs (create/describe/list)
    let mut api_latencies: Vec<f64> = Vec::with_capacity(num_jobs * 2);
    for i in 0..num_jobs {
        let request = TuningJobRequest {
            name: format!("soak-{i:04}"),
            objective: if i % 3 == 0 { "xgboost_dm" } else { "branin" }.into(),
            strategy: if i % 2 == 0 { "random" } else { "bayesian" }.into(),
            max_training_jobs: 5,
            max_parallel_jobs: 5, // the paper's example: 5 training jobs in parallel
            instance_count: if i % 10 == 0 { 100 } else { 1 }, // 100-node clusters
            seed: i as u64,
            ..Default::default()
        };
        let t = Instant::now();
        if service.create_tuning_job(request).is_ok() {
            created += 1;
        }
        api_latencies.push(t.elapsed().as_secs_f64());
        // interleave Describe/List load against the store while jobs run
        if i % 7 == 0 {
            let t = Instant::now();
            let _ = service.describe_tuning_job(&format!("soak-{:04}", i / 2));
            api_latencies.push(t.elapsed().as_secs_f64());
            let t = Instant::now();
            let _ = service.list_tuning_jobs("soak-");
            api_latencies.push(t.elapsed().as_secs_f64());
        }
    }

    let mut completed = 0usize;
    let mut evaluations = 0usize;
    let mut failed_evals = 0usize;
    let mut retries = 0u32;
    for i in 0..num_jobs {
        if let Ok(outcome) = service.wait(&format!("soak-{i:04}")) {
            completed += 1;
            evaluations += outcome.evaluations.len();
            failed_evals += outcome
                .evaluations
                .iter()
                .filter(|e| e.status == amt::platform::TrainingJobStatus::Failed)
                .count();
            retries += outcome.retries;
        }
    }
    let wall = started.elapsed().as_secs_f64();
    let jobs_per_sec = completed as f64 / wall;
    if api_latencies.is_empty() {
        eprintln!("no API calls issued for a {num_jobs}-job spike; nothing to report");
        return;
    }
    // p99 is read off a sorted copy; BenchStats::from_samples sorts
    // internally for the standard p50/p95 fields
    let mut sorted = api_latencies;
    sorted.sort_by(|a, b| a.total_cmp(b));
    let p99 = sorted[((sorted.len() - 1) as f64 * 0.99) as usize];
    let stats = BenchStats::from_samples(sorted);

    let calls = service.api_calls.load(std::sync::atomic::Ordering::Relaxed);
    let store_writes = service.store().write_count();
    let execution_plane = if distributed > 0 {
        format!("distributed ({distributed} loopback workers)")
    } else {
        format!("in-process ({} pool workers)", service.worker_count())
    };
    let rows = vec![
        vec!["tuning jobs requested".into(), num_jobs.to_string()],
        vec!["tuning jobs created".into(), created.to_string()],
        vec!["tuning jobs completed".into(), completed.to_string()],
        vec!["execution plane".into(), execution_plane],
        vec!["training jobs (evaluations)".into(), evaluations.to_string()],
        vec!["injected failures surviving retries".into(), failed_evals.to_string()],
        vec!["training-job retries absorbed".into(), retries.to_string()],
        vec!["synchronous API calls".into(), calls.to_string()],
        vec![
            "API availability".into(),
            format!("{:.4}%", service.availability() * 100.0),
        ],
        vec![
            "API latency p50 / p99".into(),
            format!("{} / {}", amt::harness::fmt_secs(stats.p50), amt::harness::fmt_secs(p99)),
        ],
        vec!["store writes".into(), store_writes.to_string()],
        vec!["wall-clock for the spike".into(), format!("{wall:.1}s")],
        vec![
            "tuning-job throughput".into(),
            format!("{jobs_per_sec:.1} jobs/s"),
        ],
    ];
    print_table(&format!("§6.5 scale soak ({num_jobs} jobs)"), &["metric", "value"], &rows);

    let label = if distributed > 0 {
        format!("soak api latency jobs={num_jobs} distributed={distributed}")
    } else {
        format!("soak api latency jobs={num_jobs}")
    };
    report.push(
        &label,
        &[
            ("jobs", num_jobs.to_string()),
            ("workers", service.worker_count().to_string()),
            ("remote_workers", distributed.to_string()),
            ("jobs_per_sec", format!("{jobs_per_sec:.2}")),
            ("api_p99_s", format!("{p99:.6}")),
            ("store_writes", store_writes.to_string()),
            ("wall_s", format!("{wall:.3}")),
        ],
        &stats,
    );

    assert_eq!(created, num_jobs, "every create call must be accepted");
    assert_eq!(completed, num_jobs, "every workflow must terminate");
    // note: Describe on not-yet-created names above is an expected 4xx; the
    // availability figure counts only those deliberate misses.
    let eval_success = 1.0 - failed_evals as f64 / evaluations as f64;
    println!(
        "\nevaluation success rate {:.2}% with {:.1}% injected failure rates \
         (retries did their job: {} absorbed)",
        eval_success * 100.0,
        (0.05 + 0.04) * 100.0,
        retries
    );

    // per-op latency histograms from the telemetry plane (DESIGN.md §15)
    let snap = service.telemetry_snapshot();
    let plane_tag = if distributed > 0 {
        format!(" distributed={distributed}")
    } else {
        String::new()
    };
    for metric in
        ["scheduler.poll_slice_us", "store.put_batch_us", "wal.commit_us", "leader.rtt_us"]
    {
        if let Some(h) = snap.histogram(metric) {
            if h.count > 0 {
                report.push_histogram(
                    &format!("soak {metric} jobs={num_jobs}{plane_tag}"),
                    &[("jobs", num_jobs.to_string()), ("metric", metric.to_string())],
                    h,
                );
            }
        }
    }

    // remote workers drain when the service (and its pool) drops
    drop(service);
    for h in worker_handles {
        let _ = h.join();
    }
}

/// Telemetry-overhead check: the same in-process spike run instrumented
/// and with `telemetry::set_enabled(false)`, reporting the throughput of
/// each and the fraction lost to instrumentation. The plane's budget is
/// ≤ 3% (DESIGN.md §15); a miss is reported loudly but not fatal —
/// wall-clock ratios on shared CI hardware are too noisy to assert on.
fn run_overhead_compare(num_jobs: usize, report: &mut BenchReport) {
    fn timed_spike(num_jobs: usize, tag: &str) -> f64 {
        let service = AmtService::new(PlatformConfig::default());
        let started = Instant::now();
        for i in 0..num_jobs {
            let request = TuningJobRequest {
                name: format!("{tag}-{i:04}"),
                objective: "branin".into(),
                strategy: "random".into(),
                max_training_jobs: 5,
                max_parallel_jobs: 5,
                seed: i as u64,
                ..Default::default()
            };
            service.create_tuning_job(request).expect("create must be accepted");
        }
        for i in 0..num_jobs {
            service.wait(&format!("{tag}-{i:04}")).expect("job must terminate");
        }
        num_jobs as f64 / started.elapsed().as_secs_f64()
    }
    eprintln!("telemetry-overhead check: {num_jobs} jobs instrumented vs disabled...");
    let on = timed_spike(num_jobs, "ovh-on");
    amt::telemetry::set_enabled(false);
    let off = timed_spike(num_jobs, "ovh-off");
    amt::telemetry::set_enabled(true);
    let overhead = (off - on) / off;
    println!(
        "\ntelemetry overhead: {on:.1} jobs/s instrumented vs {off:.1} jobs/s disabled \
         ({:+.2}% throughput)",
        -overhead * 100.0
    );
    if overhead > 0.03 {
        eprintln!("WARNING: telemetry overhead {:.2}% exceeds the 3% budget", overhead * 100.0);
    }
    let stats = BenchStats::from_samples(vec![1.0 / on, 1.0 / off]);
    report.push(
        &format!("soak telemetry overhead jobs={num_jobs}"),
        &[
            ("jobs", num_jobs.to_string()),
            ("jobs_per_sec_instrumented", format!("{on:.2}")),
            ("jobs_per_sec_disabled", format!("{off:.2}")),
            ("overhead_frac", format!("{overhead:.4}")),
        ],
        &stats,
    );
}

/// One elastic chaos spike, now driven through the load observatory
/// (DESIGN.md §16): the canned mixed workload — every create flavor plus
/// describe/list/stop/wait polling across three weighted tenants — scaled
/// so its create count approximates `num_jobs`, over a 3-worker loopback
/// fleet that loses a worker to a kill, gains a fresh one mid-run, and
/// drains another gracefully. The runner's invariant observers (job
/// conservation, counter conservation, store-version monotonicity,
/// bit-identity vs an uninterrupted reference) replace the old
/// hand-rolled completed == created assert.
fn run_chaos(num_jobs: usize, report: &mut BenchReport) {
    use amt::load::{Runner, Workload};
    // canned_mixed plans 80·scale ops of which the mix makes ~63% creates.
    let scale = (num_jobs as u32 / 50).max(1);
    let runner = Runner::new(Workload::canned_mixed("soak-chaos", 2024, scale))
        .expect("canned workload is valid");
    eprintln!(
        "chaos spike: {} mixed ops (~{num_jobs} creates requested) over an \
         elastic 3-worker fleet (kill + join + drain mid-run)...",
        runner.plan().ops.len()
    );
    let run = runner.run().expect("chaos workload completes");
    assert!(
        run.all_passed(),
        "invariant observers failed under chaos:\n{}",
        run.observers.render()
    );
    let jobs_per_sec = run.jobs_created as f64 / run.wall_s.max(1e-9);
    let rows = vec![
        vec!["mixed ops executed".into(), run.ops_executed.to_string()],
        vec!["tuning jobs created".into(), run.jobs_created.to_string()],
        vec!["training jobs (evaluations)".into(), run.evaluations.to_string()],
        vec!["chaos events fired".into(), run.chaos_fired.to_string()],
        vec!["queued jobs stolen".into(), run.pool.steals.to_string()],
        vec![
            "death requeues (snapshot / scratch)".into(),
            format!("{} / {}", run.pool.snapshot_requeues, run.pool.scratch_requeues),
        ],
        vec!["proposals re-executed".into(), run.pool.replayed_proposals.to_string()],
        vec![
            "invariant observers".into(),
            format!("{} PASS", run.observers.checks.len()),
        ],
        vec!["wall-clock".into(), format!("{:.1}s", run.wall_s)],
        vec!["throughput".into(), format!("{jobs_per_sec:.1} jobs/s")],
    ];
    print_table(
        &format!("§6.5 elastic chaos soak ({num_jobs} jobs)"),
        &["metric", "value"],
        &rows,
    );

    // Same label and param keys as the pre-observatory entry so committed
    // baselines diff cleanly; the sample distribution is now the runner's
    // real per-create latency histogram.
    let params = [
        ("jobs", run.jobs_created.to_string()),
        ("jobs_per_sec", format!("{jobs_per_sec:.2}")),
        ("joins", run.pool.joins.to_string()),
        ("drains", run.pool.drains.to_string()),
        ("steals", run.pool.steals.to_string()),
        ("snapshot_requeues", run.pool.snapshot_requeues.to_string()),
        ("scratch_requeues", run.pool.scratch_requeues.to_string()),
        ("replayed_proposals", run.pool.replayed_proposals.to_string()),
        ("wall_s", format!("{:.3}", run.wall_s)),
    ];
    let label = format!("soak chaos jobs={num_jobs}");
    match run.snapshot.histogram("load.create_us") {
        Some(h) if h.count > 0 => report.push_histogram(&label, &params, h),
        _ => report.push(
            &label,
            &params,
            &BenchStats::from_samples(vec![run.wall_s.max(1e-9)]),
        ),
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut sizes = Vec::new();
    let mut distributed = 0usize;
    let mut chaos = false;
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--distributed" {
            distributed = args
                .get(i + 1)
                .and_then(|v| v.parse().ok())
                .expect("--distributed needs a worker count");
            i += 2;
        } else if args[i] == "--chaos" {
            chaos = true;
            i += 1;
        } else {
            if let Ok(n) = args[i].parse() {
                sizes.push(n);
            }
            i += 1;
        }
    }
    let sizes = if sizes.is_empty() { vec![200] } else { sizes };
    let mut report = BenchReport::new("soak");
    for &n in &sizes {
        run_spike(n, 0, &mut report);
        if distributed > 0 {
            run_spike(n, distributed, &mut report);
        }
    }
    if chaos {
        run_chaos(*sizes.iter().max().unwrap(), &mut report);
    }
    run_overhead_compare(*sizes.iter().max().unwrap(), &mut report);
    match report.write() {
        Ok(path) => eprintln!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write BENCH_soak.json: {e}"),
    }
}
