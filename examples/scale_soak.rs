//! §6.5 deployment-scale soak test: the service absorbs a spike of tuning
//! jobs with failure injection while the synchronous APIs stay available.
//!
//! Reported (mirroring the paper's post-launch statistics):
//! * API availability (paper: ≥ 99.99% over 2020);
//! * a spike of concurrent tuning jobs, each running training jobs in
//!   parallel (paper: spikes of many hundreds of tuning jobs, requests with
//!   5 parallel training jobs, individual clusters up to 128 accelerators);
//! * workflow robustness: completed evaluations vs injected failures and
//!   the retries that absorbed them.
//!
//! ```bash
//! cargo run --release --example scale_soak [tuning_jobs]
//! ```

use std::sync::Arc;

use amt::api::AmtService;
use amt::config::TuningJobRequest;
use amt::harness::print_table;
use amt::platform::PlatformConfig;

fn main() {
    let num_jobs: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    // hostile platform: real provisioning jitter + failure injection
    let platform = PlatformConfig {
        provisioning_failure_rate: 0.05,
        training_failure_rate: 0.04,
        ..Default::default()
    };
    let service = Arc::new(AmtService::new(platform));

    eprintln!("spiking {num_jobs} tuning jobs (5 evaluations each, 5 parallel)...");
    let started = std::time::Instant::now();
    let mut created = 0usize;
    for i in 0..num_jobs {
        let request = TuningJobRequest {
            name: format!("soak-{i:04}"),
            objective: if i % 3 == 0 { "xgboost_dm" } else { "branin" }.into(),
            strategy: if i % 2 == 0 { "random" } else { "bayesian" }.into(),
            max_training_jobs: 5,
            max_parallel_jobs: 5, // the paper's example: 5 training jobs in parallel
            instance_count: if i % 10 == 0 { 100 } else { 1 }, // 100-node clusters
            seed: i as u64,
            ..Default::default()
        };
        if service.create_tuning_job(request).is_ok() {
            created += 1;
        }
        // interleave Describe/List load against the store while jobs run
        if i % 7 == 0 {
            let _ = service.describe_tuning_job(&format!("soak-{:04}", i / 2));
            let _ = service.list_tuning_jobs("soak-");
        }
    }

    let mut completed = 0usize;
    let mut evaluations = 0usize;
    let mut failed_evals = 0usize;
    let mut retries = 0u32;
    for i in 0..num_jobs {
        if let Ok(outcome) = service.wait(&format!("soak-{i:04}")) {
            completed += 1;
            evaluations += outcome.evaluations.len();
            failed_evals += outcome
                .evaluations
                .iter()
                .filter(|e| e.status == amt::platform::TrainingJobStatus::Failed)
                .count();
            retries += outcome.retries;
        }
    }
    let wall = started.elapsed().as_secs_f64();

    let calls = service.api_calls.load(std::sync::atomic::Ordering::Relaxed);
    let rows = vec![
        vec!["tuning jobs requested".into(), num_jobs.to_string()],
        vec!["tuning jobs created".into(), created.to_string()],
        vec!["tuning jobs completed".into(), completed.to_string()],
        vec!["training jobs (evaluations)".into(), evaluations.to_string()],
        vec!["injected failures surviving retries".into(), failed_evals.to_string()],
        vec!["training-job retries absorbed".into(), retries.to_string()],
        vec!["synchronous API calls".into(), calls.to_string()],
        vec![
            "API availability".into(),
            format!("{:.4}%", service.availability() * 100.0),
        ],
        vec![
            "store writes".into(),
            service.store().write_count().to_string(),
        ],
        vec!["wall-clock for the spike".into(), format!("{wall:.1}s")],
        vec![
            "tuning-job throughput".into(),
            format!("{:.1} jobs/s", completed as f64 / wall),
        ],
    ];
    print_table("§6.5 scale soak", &["metric", "value"], &rows);

    assert_eq!(created, num_jobs, "every create call must be accepted");
    assert_eq!(completed, num_jobs, "every workflow must terminate");
    // note: Describe on not-yet-created names above is an expected 4xx; the
    // availability figure counts only those deliberate misses.
    let eval_success = 1.0 - failed_evals as f64 / evaluations as f64;
    println!(
        "\nevaluation success rate {:.2}% with {:.1}% injected failure rates \
         (retries did their job: {} absorbed)",
        eval_success * 100.0,
        (0.05 + 0.04) * 100.0,
        retries
    );
}
