#!/usr/bin/env bash
# Perf-trajectory harness: run the criterion-style benches at fixed sizes
# plus the §6.5 scale-soak example, emit BENCH_propose.json /
# BENCH_gp_fit.json / BENCH_recovery.json / BENCH_soak.json, and diff p50
# latencies against the committed baselines (DESIGN.md §8).
#
# BENCH_recovery.json entries are the durability engine's trajectory
# (DESIGN.md §10): WAL append throughput, WAL replay records/sec, and
# recovery-on-open time for a 200-job store.
#
# BENCH_soak.json entries are the synchronous-API latency distribution at
# 200- and 1000-job spikes on the multi-tenant scheduler; jobs/sec, p99
# latency and the store write count ride along in each entry's params.
# With --distributed 4 each spike repeats through the loopback remote
# worker pool, so both execution planes are on the perf trajectory.
#
# BENCH_distributed.json entries are the distributed plane's own costs
# (DESIGN.md §11): frame encode/decode throughput, loopback round-trip
# latency and a 200-job soak through the RemoteWorkerPool.
#
# BENCH_load.json entries are the load observatory's per-op SLO trajectory
# (DESIGN.md §16): p50/p99/p999 of load.create_us/describe_us/... across
# the canned mixed chaos workload, plus achieved-vs-target throughput per
# steady/ramp/burst phase. Emitted only if every invariant observer passed.
#
# Usage:
#   scripts/bench.sh            # run + diff (fails on >TOLERANCE regressions)
#   scripts/bench.sh --update   # run + overwrite the committed baselines
#
# TOLERANCE: allowed p50 slowdown ratio before the diff fails (default 1.30).
set -euo pipefail
cd "$(dirname "$0")/.."

TOLERANCE="${TOLERANCE:-1.30}"
MODE="${1:-check}"

run_dir="$(mktemp -d)"
trap 'rm -rf "$run_dir"' EXIT

echo "== running benches (fresh JSON into $run_dir) =="
AMT_BENCH_DIR="$run_dir" cargo bench --bench bo_propose
AMT_BENCH_DIR="$run_dir" cargo bench --bench gp_fit
echo "== running recovery bench (WAL append/replay + 200-job open) =="
AMT_BENCH_DIR="$run_dir" cargo bench --bench recovery
echo "== running distributed bench (frame codec, loopback RTT, remote soak) =="
AMT_BENCH_DIR="$run_dir" cargo bench --bench distributed
echo "== running load observatory (canned mixed chaos workload, DESIGN.md §16) =="
AMT_BENCH_DIR="$run_dir" cargo bench --bench load
echo "== running scale soak (200- and 1000-job spikes, both planes) =="
AMT_BENCH_DIR="$run_dir" cargo run --release --example scale_soak -- 200 1000 --distributed 4

status=0
for f in BENCH_propose.json BENCH_gp_fit.json BENCH_recovery.json BENCH_distributed.json BENCH_soak.json BENCH_load.json; do
    fresh="$run_dir/$f"
    if [ ! -f "$fresh" ]; then
        echo "ERROR: bench did not produce $f" >&2
        status=1
        continue
    fi
    if [ "$MODE" = "--update" ] || [ ! -s "$f" ] || ! grep -q '"p50_s"' "$f"; then
        # --update, or no committed baseline with real entries yet: bootstrap.
        # Never let an empty placeholder (a run whose entries all failed to
        # produce p50_s) clobber a populated baseline.
        if grep -q '"p50_s"' "$f" 2>/dev/null && ! grep -q '"p50_s"' "$fresh"; then
            echo "ERROR: refusing to overwrite populated $f with an empty placeholder" >&2
            status=1
            continue
        fi
        if [ "$MODE" != "--update" ]; then
            # A committed baseline with zero real entries means this file has
            # never been measured: the diff below would trivially pass with
            # every fresh entry marked NEW. Say so explicitly.
            echo "WARNING: $f BASELINE MISSING — run with --update on a toolchain machine"
        fi
        cp "$fresh" "$f"
        echo "baseline written: $f"
        continue
    fi
    echo "== diff $f (tolerance ${TOLERANCE}x) =="
    python3 - "$f" "$fresh" "$TOLERANCE" <<'PY' || status=1
import json, sys
base_path, fresh_path, tol = sys.argv[1], sys.argv[2], float(sys.argv[3])
base = {e["label"]: e for e in json.load(open(base_path))["entries"]}
fresh = {e["label"]: e for e in json.load(open(fresh_path))["entries"]}
failed = False
for label, fe in fresh.items():
    be = base.get(label)
    if be is None:
        print(f"  NEW    {label}: p50 {fe['p50_s']*1e3:.2f}ms")
        continue
    ratio = fe["p50_s"] / be["p50_s"] if be["p50_s"] > 0 else float("inf")
    mark = "OK " if ratio <= tol else "REG"
    if ratio > tol:
        failed = True
    print(f"  {mark}    {label}: p50 {be['p50_s']*1e3:.2f}ms -> "
          f"{fe['p50_s']*1e3:.2f}ms ({ratio:.2f}x)")
for label in base:
    if label not in fresh:
        print(f"  GONE   {label} (present in baseline only)")
sys.exit(1 if failed else 0)
PY
done

if [ "$status" -ne 0 ]; then
    echo "bench diff FAILED (regression beyond ${TOLERANCE}x or missing output)" >&2
    echo "re-run with scripts/bench.sh --update to accept the new numbers" >&2
fi
exit "$status"
