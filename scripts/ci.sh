#!/usr/bin/env bash
# One-command tier-1 gate for toolchain machines (and CI): release build
# plus the full test suite — exactly the verify line ROADMAP.md names.
#
# Usage:
#   scripts/ci.sh          # build + test
#   scripts/ci.sh --bench  # additionally run the perf-trajectory harness
#                          # (scripts/bench.sh: fails on p50 regressions)
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "ERROR: no cargo toolchain on PATH." >&2
    echo "  This gate needs rustc/cargo (the authoring containers for PRs 1+ had" >&2
    echo "  none — see CHANGES.md). Install a Rust toolchain (e.g. via rustup)" >&2
    echo "  and re-run: scripts/ci.sh [--bench]" >&2
    exit 1
fi

echo "== tier-1 verify: cargo build --release =="
cargo build --release

echo "== tier-1 verify: cargo build --benches --examples =="
cargo build --release --benches --examples

echo "== tier-1 verify: cargo test -q =="
cargo test -q

# fast elastic-fleet chaos smoke (DESIGN.md §13): 64 jobs through a
# kill + late join + graceful drain, bit-identical to an uninterrupted
# run. Redundant with the full suite above on clean runs, but called out
# so a chaos regression fails with its own named step.
echo "== chaos smoke: kill + join + drain (64 jobs) =="
cargo test -q --release --test elastic_chaos fast_chaos_smoke

# throughput smoke (DESIGN.md §14): a small durable loopback fleet with a
# group-commit window. Asserts concurrent lane drivers shared fsyncs
# (wal_coalesced > 0) and the coalesced wire stayed well under the legacy
# two frames per slice.
echo "== throughput smoke: group commit + coalesced slices (16 jobs) =="
cargo test -q --release --test throughput throughput_smoke

# telemetry smoke (DESIGN.md §15): a 16-job durable loopback fleet must
# leave nonzero wal.commit_us latency samples, one complete propose →
# … → outcome trace per job, and a telemetry snapshot whose JSON (the
# `amt stats --json` surface) parses back through the crate's own parser.
echo "== telemetry smoke: metrics + trace lifecycle (16 jobs) =="
cargo test -q --release --test throughput telemetry_smoke

# pipeline smoke (DESIGN.md §17): a 16-job BO fleet with the speculative
# proposal pipeline and the cross-job evaluation cache enabled. Asserts
# strategy.speculation_hits > 0 and cache.hits > 0 in the telemetry
# snapshot, and that cached trajectories replay bit-identically.
echo "== pipeline smoke: speculation + evaluation cache (16 BO jobs) =="
cargo test -q --release --test eval_cache pipeline_smoke

# load smoke (DESIGN.md §16): ~10 s declarative mixed workload (every
# create flavor plus describe/list/stop/wait polling) on the loopback
# distributed plane with one worker kill, one late join and one graceful
# drain. Every invariant observer (job conservation, terminal status,
# store-version monotonicity, counter conservation, replay attribution,
# bit-identity vs an uninterrupted reference) must pass and the per-op
# load.* SLO histograms must be nonzero.
echo "== load smoke: mixed workload + kill/join/drain observers =="
cargo test -q --release --test load_harness load_smoke

if [ "${1:-}" = "--bench" ]; then
    echo "== perf trajectory: scripts/bench.sh =="
    scripts/bench.sh
fi

echo "ci: OK"
