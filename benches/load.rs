//! Load-observatory perf trajectory → BENCH_load.json (DESIGN.md §16).
//!
//! Runs the canned mixed chaos workload (every create flavor, polling
//! traffic, kill / late-join / drain on a 3-worker loopback fleet) and
//! emits:
//!   - one histogram entry per op kind (`load.create_us`, …) with
//!     p50/p99/p999 in seconds via `BenchReport::push_histogram`;
//!   - one entry per phase with the achieved-vs-target throughput in the
//!     params and the phase wall time as the sample;
//!   - one overall entry (total ops/sec over the whole run).
//!
//! Invariant observers run as part of the workload; the bench aborts if
//! any fails — a perf number from a run that lost jobs is meaningless.

use amt::harness::{BenchReport, BenchStats};
use amt::load::{Runner, Workload};

fn main() {
    let workload = Workload::canned_mixed("bench-load", 42, 3);
    let runner = Runner::new(workload).expect("canned workload is valid");
    println!(
        "load bench: {} planned ops, {} chaos events",
        runner.plan().ops.len(),
        runner.plan().chaos_count()
    );
    let report = runner.run().expect("load run completes");
    assert!(
        report.all_passed(),
        "invariant observers failed — refusing to emit perf numbers:\n{}",
        report.observers.render()
    );

    let mut bench = BenchReport::new("load");
    let jobs = report.jobs_created.to_string();

    for op in ["create", "describe", "list", "stop", "wait"] {
        let name = format!("load.{op}_us");
        if let Some(h) = report.snapshot.histogram(&name) {
            if h.count == 0 {
                continue;
            }
            bench.push_histogram(
                &format!("mixed {name}"),
                &[
                    ("metric", name.clone()),
                    ("ops", h.count.to_string()),
                    ("jobs", jobs.clone()),
                ],
                h,
            );
            println!(
                "  {name}: n={} p50={}us p99={}us p999={}us",
                h.count, h.p50, h.p99, h.p999
            );
        }
    }

    for phase in &report.phases {
        bench.push(
            &format!("mixed phase {}", phase.kind.as_str()),
            &[
                ("ops", phase.ops.to_string()),
                ("target_rate", format!("{:.1}", phase.target_rate)),
                ("achieved_rate", format!("{:.1}", phase.achieved_rate)),
            ],
            &BenchStats::from_samples(vec![phase.wall_s.max(1e-9)]),
        );
        println!(
            "  phase {}: {} ops, target {:.0}/s achieved {:.0}/s",
            phase.kind.as_str(),
            phase.ops,
            phase.target_rate,
            phase.achieved_rate
        );
    }

    let overall_rate = report.ops_executed as f64 / report.wall_s.max(1e-9);
    bench.push(
        "mixed overall",
        &[
            ("ops", report.ops_executed.to_string()),
            ("jobs", jobs),
            ("evaluations", report.evaluations.to_string()),
            ("chaos", report.chaos_fired.to_string()),
            ("achieved_rate", format!("{overall_rate:.1}")),
        ],
        &BenchStats::from_samples(vec![report.wall_s.max(1e-9)]),
    );

    let path = bench.write().expect("write BENCH_load.json");
    println!(
        "load bench: {} ops at {:.0} ops/s overall -> {}",
        report.ops_executed,
        overall_rate,
        path.display()
    );
}
