//! L3 service-path benches: metadata-store writes, conditional writes,
//! metric emission, platform event processing, and whole tuning-job
//! throughput (random search, so the measured cost is pure coordinator).
//! The coordinator must never be the bottleneck unless the contribution is
//! the coordinator itself (§Perf targets in DESIGN.md).
//! `cargo bench --bench service_throughput`.

use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use amt::config::TuningJobRequest;
use amt::coordinator::{stopping_by_name, TuningJobRunner};
use amt::gp::NativeBackend;
use amt::harness::{bench, print_table};
use amt::json::Json;
use amt::metrics::MetricsService;
use amt::platform::{PlatformConfig, TrainingPlatform, TrainingJobSpec};
use amt::store::MetadataStore;
use amt::strategies;

fn main() {
    let mut rows = Vec::new();

    // store puts
    let store = MetadataStore::new();
    let mut i = 0u64;
    let s = bench("store put", 100, 50_000, || {
        i += 1;
        store.put("t", &format!("k{}", i % 1000), Json::Num(i as f64));
    });
    rows.push(vec!["store put".into(), format!("{:.0}/s", 1.0 / s.mean)]);

    let mut ver = store.put("t", "cond", Json::Num(0.0));
    let s = bench("store conditional put", 100, 50_000, || {
        ver = store.put_if("t", "cond", Json::Num(ver as f64), Some(ver)).unwrap();
    });
    rows.push(vec!["store put_if".into(), format!("{:.0}/s", 1.0 / s.mean)]);

    // metric emission
    let metrics = MetricsService::new();
    let mut t = 0.0;
    let s = bench("metrics emit", 100, 50_000, || {
        t += 1.0;
        metrics.emit("bench/stream", t, t * 0.5);
    });
    rows.push(vec!["metrics emit".into(), format!("{:.0}/s", 1.0 / s.mean)]);

    // platform event pump (submit + drain batches of jobs)
    let objective: Arc<dyn amt::objectives::Objective> =
        amt::objectives::by_name("branin").unwrap().into();
    let mut rng = amt::rng::Rng::new(3);
    let s = bench("platform 50-job drain", 2, 50, || {
        let mut p = TrainingPlatform::new(PlatformConfig::default(), 7);
        for j in 0..50 {
            p.submit(TrainingJobSpec {
                name: format!("b{j}"),
                config: objective.space().sample(&mut rng),
                objective: Arc::clone(&objective),
                seed: j,
                instance_count: 1,
            });
        }
        while p.next_event().is_some() {}
    });
    // 50 jobs × (1 start + 5 epochs) events
    rows.push(vec![
        "platform events".into(),
        format!("{:.0}/s", 50.0 * 6.0 / s.mean),
    ]);

    // full tuning job, random search (coordinator overhead only)
    let s = bench("tuning job (20 evals, random)", 1, 20, || {
        let request = TuningJobRequest {
            name: "bench".into(),
            objective: "branin".into(),
            strategy: "random".into(),
            max_training_jobs: 20,
            max_parallel_jobs: 4,
            ..Default::default()
        };
        let strat = strategies::by_name(
            "random",
            &objective.space(),
            Arc::new(NativeBackend),
            1,
        )
        .unwrap();
        let out = TuningJobRunner::new(
            request,
            Arc::clone(&objective),
            strat,
            stopping_by_name("off").unwrap(),
            TrainingPlatform::new(PlatformConfig::default(), 1),
            Arc::new(MetadataStore::new()),
            Arc::new(MetricsService::new()),
            Arc::new(AtomicBool::new(false)),
        )
        .run();
        std::hint::black_box(out);
    });
    rows.push(vec![
        "coordinator per evaluation".into(),
        amt::harness::fmt_secs(s.mean / 20.0),
    ]);

    print_table("service throughput", &["operation", "rate / latency"], &rows);
}
