//! Durability-engine benchmark (DESIGN.md §10/§12): WAL append
//! throughput, WAL replay rate, recovery-on-open time for a 200-job
//! store, and the incremental-resume comparison — scratch-replay vs
//! snapshot-resume recovery of a 200-job durable service killed
//! mid-spike, with the "strategy proposals re-executed during recovery"
//! counter (must be 0 on the snapshot fast path).
//! Emits `BENCH_recovery.json` (schema in `harness::BenchReport`;
//! `AMT_BENCH_DIR` overrides the output directory).
//! `cargo bench --bench recovery`.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use amt::api::{AmtService, RecoveryStats};
use amt::config::TuningJobRequest;
use amt::coordinator::checkpoint_cursor;
use amt::durability::wal::{Wal, WalRecord, WAL_FILE};
use amt::gp::NativeBackend;
use amt::harness::{bench, BenchReport};
use amt::json::Json;
use amt::platform::PlatformConfig;
use amt::scheduler::SchedulerConfig;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "amt-bench-recovery-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn main() {
    let mut report = BenchReport::new("recovery");
    const WAL_RECORDS: usize = 100_000;
    const RECOVERY_JOBS: usize = 200;

    // --- WAL append throughput (fsync off: framing + buffering + one
    // write, the cost the store's hot path pays per mutation) ---
    let append_dir = tmpdir("append");
    let stats = bench("wal append+commit 100k puts", 1, 5, || {
        let wal = Wal::create(&append_dir).unwrap();
        wal.set_fsync(false);
        for i in 0..WAL_RECORDS {
            wal.append(&WalRecord::Put {
                table: "training_jobs".into(),
                key: format!("job-{:05}", i % 1000),
                version: (i / 1000 + 1) as u64,
                value: Json::obj(vec![
                    ("status", Json::Str("Completed".into())),
                    ("final_value", Json::Num(i as f64 * 0.5)),
                ]),
            });
        }
        wal.commit().unwrap();
    });
    report.push(
        "wal_append_100k",
        &[
            ("records", WAL_RECORDS.to_string()),
            ("records_per_sec", format!("{:.0}", WAL_RECORDS as f64 / stats.p50)),
            ("fsync", "off".into()),
        ],
        &stats,
    );

    // --- WAL replay (scan) rate over the same file ---
    let wal_path = append_dir.join(WAL_FILE);
    let stats = bench("wal scan 100k records", 1, 5, || {
        let scan = Wal::scan(&wal_path).unwrap();
        assert_eq!(scan.records.len(), WAL_RECORDS);
        std::hint::black_box(scan.valid_len);
    });
    report.push(
        "wal_replay_100k",
        &[
            ("records", WAL_RECORDS.to_string()),
            ("records_per_sec", format!("{:.0}", WAL_RECORDS as f64 / stats.p50)),
        ],
        &stats,
    );

    // --- recovery-on-open for a 200-job service (WAL-only: no snapshot,
    // so open replays the whole mutation history) ---
    let svc_dir = tmpdir("open200");
    let wal_records;
    {
        let svc = AmtService::open(&svc_dir, PlatformConfig::noiseless()).unwrap();
        svc.wal().unwrap().set_fsync(false); // prep speed; replay is unaffected
        for i in 0..RECOVERY_JOBS {
            svc.create_tuning_job(TuningJobRequest {
                name: format!("rec-{i:04}"),
                objective: "branin".into(),
                strategy: "random".into(),
                max_training_jobs: 2,
                max_parallel_jobs: 2,
                seed: i as u64,
                ..Default::default()
            })
            .unwrap();
        }
        for i in 0..RECOVERY_JOBS {
            svc.wait(&format!("rec-{i:04}")).unwrap();
        }
        svc.wal().unwrap().commit().unwrap();
        wal_records = Wal::scan(&svc_dir.join(WAL_FILE)).unwrap().records.len();
        // drop without close(): crash-style teardown, WAL-only recovery
    }
    let stats = bench("open: recover 200 completed jobs", 0, 3, || {
        let svc = AmtService::open(&svc_dir, PlatformConfig::noiseless()).unwrap();
        assert_eq!(svc.list_tuning_jobs("rec-").len(), RECOVERY_JOBS);
        std::hint::black_box(svc.recovered_jobs().len());
    });
    report.push(
        "recovery_open_200_jobs",
        &[
            ("jobs", RECOVERY_JOBS.to_string()),
            ("wal_records", wal_records.to_string()),
            ("records_per_sec", format!("{:.0}", wal_records as f64 / stats.p50)),
        ],
        &stats,
    );

    // --- incremental resume: scratch-replay vs snapshot-resume for the
    // same 200-job durable service killed mid-spike (DESIGN.md §12).
    // One worker keeps slices contiguous in the WAL, so a cut right
    // after the last checkpoint leaves every polled-but-unfinished job
    // with an aligned v1 snapshot (fast path, 0 re-executed proposals)
    // and unpolled jobs with only their create records (0 proposals
    // either way). Rewriting the same prefix's checkpoints to legacy v0
    // cursors forces the pre-v1 scratch path on identical work. ---
    let resume_src = tmpdir("resume-src");
    {
        let svc = AmtService::open_with_options(
            &resume_src,
            PlatformConfig::noiseless(),
            Arc::new(NativeBackend),
            SchedulerConfig { workers: 1, batch_steps: 8 },
        )
        .unwrap();
        svc.wal().unwrap().set_fsync(false);
        for i in 0..RECOVERY_JOBS {
            svc.create_tuning_job(TuningJobRequest {
                name: format!("res-{i:04}"),
                objective: "branin".into(),
                strategy: "random".into(),
                max_training_jobs: 3,
                max_parallel_jobs: 2,
                seed: 900 + i as u64,
                ..Default::default()
            })
            .unwrap();
        }
        for i in 0..RECOVERY_JOBS {
            svc.wait(&format!("res-{i:04}")).unwrap();
        }
        svc.wal().unwrap().commit().unwrap();
        // crash-style teardown
    }
    let full = std::fs::read(resume_src.join(WAL_FILE)).unwrap();
    let scan = Wal::scan(&resume_src.join(WAL_FILE)).unwrap();
    // kill point: right after the checkpoint at ~60% of the log
    let ckpt_idxs: Vec<usize> = scan
        .records
        .iter()
        .enumerate()
        .filter(|(_, (_, r))| matches!(r, WalRecord::Checkpoint { .. }))
        .map(|(i, _)| i)
        .collect();
    let cut_idx = ckpt_idxs[ckpt_idxs.len() * 6 / 10];
    let prefix = &full[..scan.frame_ends[cut_idx] as usize];
    // the same prefix with every checkpoint stripped to a legacy v0
    // cursor: recovery must fall back to scratch replay
    let v0_prefix = {
        let dir = tmpdir("resume-v0-build");
        let wal = Wal::create(&dir).unwrap();
        wal.set_fsync(false);
        for (_, rec) in &Wal::decode_frames(prefix).records {
            let rec = match rec {
                WalRecord::Checkpoint { job, exec } => WalRecord::Checkpoint {
                    job: job.clone(),
                    exec: checkpoint_cursor(exec).expect("cursor parses").to_json(),
                },
                other => other.clone(),
            };
            wal.append(&rec);
        }
        wal.commit().unwrap();
        let bytes = std::fs::read(dir.join(WAL_FILE)).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        bytes
    };

    fn run_recovery(dir: &Path, bytes: &[u8]) -> RecoveryStats {
        let _ = std::fs::remove_dir_all(dir);
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join(WAL_FILE), bytes).unwrap();
        let svc = AmtService::open_with_options(
            dir,
            PlatformConfig::noiseless(),
            Arc::new(NativeBackend),
            SchedulerConfig { workers: 4, batch_steps: 8 },
        )
        .unwrap();
        svc.wal().unwrap().set_fsync(false);
        for name in svc.recovered_jobs().to_vec() {
            svc.wait(&name).unwrap();
        }
        svc.recovery_stats()
    }

    let snap_dir = tmpdir("resume-snap");
    let mut snap_stats = RecoveryStats::default();
    let stats = bench("snapshot-resume: 200-job kill + open + finish", 0, 3, || {
        snap_stats = run_recovery(&snap_dir, prefix);
    });
    assert_eq!(
        snap_stats.replayed_proposals, 0,
        "snapshot fast path must re-execute 0 strategy proposals \
         (fast={}, scratch={})",
        snap_stats.fast_resumed, snap_stats.scratch_resumed
    );
    report.push(
        "resume_snapshot_200_jobs",
        &[
            ("jobs", RECOVERY_JOBS.to_string()),
            ("fast_resumed", snap_stats.fast_resumed.to_string()),
            ("scratch_resumed", snap_stats.scratch_resumed.to_string()),
            ("replayed_proposals", snap_stats.replayed_proposals.to_string()),
        ],
        &stats,
    );

    let scratch_dir = tmpdir("resume-scratch");
    let mut scratch_stats = RecoveryStats::default();
    let stats = bench("scratch-replay: 200-job kill + open + finish", 0, 3, || {
        scratch_stats = run_recovery(&scratch_dir, &v0_prefix);
    });
    assert_eq!(scratch_stats.fast_resumed, 0, "v0 checkpoints must not fast-path");
    report.push(
        "resume_scratch_200_jobs",
        &[
            ("jobs", RECOVERY_JOBS.to_string()),
            ("fast_resumed", scratch_stats.fast_resumed.to_string()),
            ("scratch_resumed", scratch_stats.scratch_resumed.to_string()),
            ("replayed_proposals", scratch_stats.replayed_proposals.to_string()),
        ],
        &stats,
    );
    println!(
        "resume comparison: snapshot fast={} scratch={} proposals=0 | \
         v0 scratch={} proposals={}",
        snap_stats.fast_resumed,
        snap_stats.scratch_resumed,
        scratch_stats.scratch_resumed,
        scratch_stats.replayed_proposals
    );

    match report.write() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("failed to write BENCH_recovery.json: {e}"),
    }
    let _ = std::fs::remove_dir_all(&append_dir);
    let _ = std::fs::remove_dir_all(&svc_dir);
    let _ = std::fs::remove_dir_all(&resume_src);
    let _ = std::fs::remove_dir_all(&snap_dir);
    let _ = std::fs::remove_dir_all(&scratch_dir);
}
