//! Durability-engine benchmark (DESIGN.md §10): WAL append throughput,
//! WAL replay rate, and recovery-on-open time for a 200-job store.
//! Emits `BENCH_recovery.json` (schema in `harness::BenchReport`;
//! `AMT_BENCH_DIR` overrides the output directory).
//! `cargo bench --bench recovery`.

use std::path::PathBuf;

use amt::api::AmtService;
use amt::config::TuningJobRequest;
use amt::durability::wal::{Wal, WalRecord, WAL_FILE};
use amt::harness::{bench, BenchReport};
use amt::json::Json;
use amt::platform::PlatformConfig;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "amt-bench-recovery-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn main() {
    let mut report = BenchReport::new("recovery");
    const WAL_RECORDS: usize = 100_000;
    const RECOVERY_JOBS: usize = 200;

    // --- WAL append throughput (fsync off: framing + buffering + one
    // write, the cost the store's hot path pays per mutation) ---
    let append_dir = tmpdir("append");
    let stats = bench("wal append+commit 100k puts", 1, 5, || {
        let wal = Wal::create(&append_dir).unwrap();
        wal.set_fsync(false);
        for i in 0..WAL_RECORDS {
            wal.append(&WalRecord::Put {
                table: "training_jobs".into(),
                key: format!("job-{:05}", i % 1000),
                version: (i / 1000 + 1) as u64,
                value: Json::obj(vec![
                    ("status", Json::Str("Completed".into())),
                    ("final_value", Json::Num(i as f64 * 0.5)),
                ]),
            });
        }
        wal.commit().unwrap();
    });
    report.push(
        "wal_append_100k",
        &[
            ("records", WAL_RECORDS.to_string()),
            ("records_per_sec", format!("{:.0}", WAL_RECORDS as f64 / stats.p50)),
            ("fsync", "off".into()),
        ],
        &stats,
    );

    // --- WAL replay (scan) rate over the same file ---
    let wal_path = append_dir.join(WAL_FILE);
    let stats = bench("wal scan 100k records", 1, 5, || {
        let scan = Wal::scan(&wal_path).unwrap();
        assert_eq!(scan.records.len(), WAL_RECORDS);
        std::hint::black_box(scan.valid_len);
    });
    report.push(
        "wal_replay_100k",
        &[
            ("records", WAL_RECORDS.to_string()),
            ("records_per_sec", format!("{:.0}", WAL_RECORDS as f64 / stats.p50)),
        ],
        &stats,
    );

    // --- recovery-on-open for a 200-job service (WAL-only: no snapshot,
    // so open replays the whole mutation history) ---
    let svc_dir = tmpdir("open200");
    let wal_records;
    {
        let svc = AmtService::open(&svc_dir, PlatformConfig::noiseless()).unwrap();
        svc.wal().unwrap().set_fsync(false); // prep speed; replay is unaffected
        for i in 0..RECOVERY_JOBS {
            svc.create_tuning_job(TuningJobRequest {
                name: format!("rec-{i:04}"),
                objective: "branin".into(),
                strategy: "random".into(),
                max_training_jobs: 2,
                max_parallel_jobs: 2,
                seed: i as u64,
                ..Default::default()
            })
            .unwrap();
        }
        for i in 0..RECOVERY_JOBS {
            svc.wait(&format!("rec-{i:04}")).unwrap();
        }
        svc.wal().unwrap().commit().unwrap();
        wal_records = Wal::scan(&svc_dir.join(WAL_FILE)).unwrap().records.len();
        // drop without close(): crash-style teardown, WAL-only recovery
    }
    let stats = bench("open: recover 200 completed jobs", 0, 3, || {
        let svc = AmtService::open(&svc_dir, PlatformConfig::noiseless()).unwrap();
        assert_eq!(svc.list_tuning_jobs("rec-").len(), RECOVERY_JOBS);
        std::hint::black_box(svc.recovered_jobs().len());
    });
    report.push(
        "recovery_open_200_jobs",
        &[
            ("jobs", RECOVERY_JOBS.to_string()),
            ("wal_records", wal_records.to_string()),
            ("records_per_sec", format!("{:.0}", wal_records as f64 / stats.p50)),
        ],
        &stats,
    );

    match report.write() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("failed to write BENCH_recovery.json: {e}"),
    }
    let _ = std::fs::remove_dir_all(&append_dir);
    let _ = std::fs::remove_dir_all(&svc_dir);
}
