//! End-to-end proposal latency: one full BO round (GPHP fit + posterior
//! factorization + Sobol-anchor scoring + local EI refinement) as a
//! function of the observation count, on the native and HLO backends.
//! This is the per-decision service latency the Hyperparameter Selection
//! Service adds between training jobs. `cargo bench --bench bo_propose`.
//!
//! Emits `BENCH_propose.json` alongside the printed table so the perf
//! trajectory is tracked across PRs (`scripts/bench.sh` diffs it against
//! the committed baseline).

use std::sync::Arc;

use amt::acquisition::AcquisitionConfig;
use amt::gp::{NativeBackend, SurrogateBackend};
use amt::harness::{bench, print_table, BenchReport};
use amt::rng::Rng;
use amt::runtime::{HloBackend, HloRuntime};
use amt::space::{continuous, Scaling, SearchSpace};
use amt::strategies::{BayesianOptimization, BoConfig, GphpMode, Observation, Strategy};

fn space(d: usize) -> SearchSpace {
    SearchSpace::new(
        (0..d)
            .map(|i| continuous(&format!("x{i}"), 0.0, 1.0, Scaling::Linear))
            .collect(),
    )
    .unwrap()
}

fn history(space: &SearchSpace, n: usize, seed: u64) -> Vec<Observation> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let config = space.sample(&mut rng);
            let v: f64 = config.values().filter_map(|v| v.as_f64()).map(|x| (x - 0.4).powi(2)).sum();
            Observation { config, value: v }
        })
        .collect()
}

fn main() {
    let d = 6;
    let sp = space(d);
    let backends: Vec<(&str, Arc<dyn SurrogateBackend>)> = {
        let mut v: Vec<(&str, Arc<dyn SurrogateBackend>)> =
            vec![("native", Arc::new(NativeBackend))];
        match HloRuntime::open_default() {
            Ok(rt) => v.push(("hlo", Arc::new(HloBackend::new(rt)))),
            Err(_) => eprintln!("NOTE: artifacts missing; hlo rows skipped"),
        }
        v
    };

    let mut report = BenchReport::new("propose");
    let mut rows = Vec::new();
    for n in [10usize, 25, 50, 100, 200] {
        let hist = history(&sp, n, n as u64);
        let mut cells = vec![n.to_string()];
        for (bname, backend) in &backends {
            let mut bo = BayesianOptimization::new(
                sp.clone(),
                Arc::clone(backend),
                BoConfig {
                    init_random: 4,
                    gphp: GphpMode::Mcmc(amt::gp::slice::SliceConfig::light()),
                    acq: AcquisitionConfig { num_anchors: 512, ..Default::default() },
                    ..Default::default()
                },
                1,
            );
            let iters = if n <= 50 { 5 } else { 3 };
            let stats = bench(&format!("propose {bname:>6} n={n}"), 1, iters, || {
                let c = bo.next_config(&hist, &[]);
                std::hint::black_box(c);
            });
            report.push(
                &format!("propose {bname} n={n}"),
                &[
                    ("backend", bname.to_string()),
                    ("n", n.to_string()),
                    ("d", d.to_string()),
                    ("anchors", "512".to_string()),
                    ("gphp", "mcmc-light".to_string()),
                ],
                &stats,
            );
            cells.push(amt::harness::fmt_secs(stats.p50));
        }
        rows.push(cells);
    }
    let header: Vec<&str> = std::iter::once("n")
        .chain(backends.iter().map(|(n, _)| *n))
        .collect();
    print_table("BO proposal p50 latency (light MCMC, 512 anchors)", &header, &rows);
    match report.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("WARN: could not write bench report: {e}"),
    }
}
