//! End-to-end proposal latency: one full BO round (GPHP fit + posterior
//! factorization + Sobol-anchor scoring + local EI refinement) as a
//! function of the observation count, on the native and HLO backends.
//! This is the per-decision service latency the Hyperparameter Selection
//! Service adds between training jobs. `cargo bench --bench bo_propose`.
//!
//! Emits `BENCH_propose.json` alongside the printed table so the perf
//! trajectory is tracked across PRs (`scripts/bench.sh` diffs it against
//! the committed baseline).

use std::sync::Arc;

use amt::acquisition::AcquisitionConfig;
use amt::gp::{NativeBackend, SurrogateBackend};
use amt::harness::{bench, print_table, BenchReport};
use amt::rng::Rng;
use amt::runtime::{HloBackend, HloRuntime};
use amt::space::{continuous, Scaling, SearchSpace};
use amt::strategies::{BayesianOptimization, BoConfig, GphpMode, Observation, Strategy};

fn space(d: usize) -> SearchSpace {
    SearchSpace::new(
        (0..d)
            .map(|i| continuous(&format!("x{i}"), 0.0, 1.0, Scaling::Linear))
            .collect(),
    )
    .unwrap()
}

fn history(space: &SearchSpace, n: usize, seed: u64) -> Vec<Observation> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let config = space.sample(&mut rng);
            let v: f64 = config.values().filter_map(|v| v.as_f64()).map(|x| (x - 0.4).powi(2)).sum();
            Observation { config, value: v }
        })
        .collect()
}

fn main() {
    let d = 6;
    let sp = space(d);
    let backends: Vec<(&str, Arc<dyn SurrogateBackend>)> = {
        let mut v: Vec<(&str, Arc<dyn SurrogateBackend>)> =
            vec![("native", Arc::new(NativeBackend))];
        match HloRuntime::open_default() {
            Ok(rt) => v.push(("hlo", Arc::new(HloBackend::new(rt)))),
            Err(_) => eprintln!("NOTE: artifacts missing; hlo rows skipped"),
        }
        v
    };

    let mut report = BenchReport::new("propose");
    let mut rows = Vec::new();
    for n in [10usize, 25, 50, 100, 200] {
        let hist = history(&sp, n, n as u64);
        let mut cells = vec![n.to_string()];
        for (bname, backend) in &backends {
            let mut bo = BayesianOptimization::new(
                sp.clone(),
                Arc::clone(backend),
                BoConfig {
                    init_random: 4,
                    gphp: GphpMode::Mcmc(amt::gp::slice::SliceConfig::light()),
                    acq: AcquisitionConfig { num_anchors: 512, ..Default::default() },
                    ..Default::default()
                },
                1,
            );
            let iters = if n <= 50 { 5 } else { 3 };
            let stats = bench(&format!("propose {bname:>6} n={n}"), 1, iters, || {
                let c = bo.next_config(&hist, &[]);
                std::hint::black_box(c);
            });
            report.push(
                &format!("propose {bname} n={n}"),
                &[
                    ("backend", bname.to_string()),
                    ("n", n.to_string()),
                    ("d", d.to_string()),
                    ("anchors", "512".to_string()),
                    ("gphp", "mcmc-light".to_string()),
                ],
                &stats,
            );
            cells.push(amt::harness::fmt_secs(stats.p50));
        }
        rows.push(cells);
    }
    let header: Vec<&str> = std::iter::once("n")
        .chain(backends.iter().map(|(n, _)| *n))
        .collect();
    print_table("BO proposal p50 latency (light MCMC, 512 anchors)", &header, &rows);

    // Pipeline scenarios (DESIGN.md §17): the latency left on the
    // critical path between a landed outcome and the next launch.
    // "sync" is the full BO round the actor runs on that path today;
    // "pipelined-commit" is the validity check that replaces it when an
    // idle-tail speculation commits; "cache-hit" is the store lookup
    // that replaces a whole training job for an already-seen config.
    let mk_bo = || {
        BayesianOptimization::new(
            sp.clone(),
            Arc::new(NativeBackend) as Arc<dyn SurrogateBackend>,
            BoConfig {
                init_random: 4,
                gphp: GphpMode::Mcmc(amt::gp::slice::SliceConfig::light()),
                acq: AcquisitionConfig { num_anchors: 512, ..Default::default() },
                ..Default::default()
            },
            1,
        )
    };
    let n = 50;
    let hist = history(&sp, n, n as u64);

    let mut bo = mk_bo();
    let sync_stats = bench("propose sync n=50", 1, 5, || {
        let c = bo.next_config(&hist, &[]);
        std::hint::black_box(c);
    });
    report.push(
        "propose sync n=50",
        &[("mode", "synchronous".to_string()), ("n", n.to_string())],
        &sync_stats,
    );

    // speculate in the (free) idle tail, then land the real outcome
    // bit-equal to the fantasy so every timed iteration takes the
    // commit path
    let base = &hist[..n - 1];
    let landed_cfg = hist[n - 1].config.clone();
    let mut bo = mk_bo();
    let spec = amt::strategies::speculate(&mut bo, base, &[], landed_cfg.clone());
    let mut landed = base.to_vec();
    landed.push(Observation { config: landed_cfg, value: spec.fantasy_value });
    assert!(spec.matches(&landed, &[]), "bench must exercise the commit path");
    let commit_stats = bench("propose pipelined-commit n=50", 10, 2000, || {
        let hit = spec.matches(&landed, &[]);
        std::hint::black_box((hit, &spec.config));
    });
    report.push(
        "propose pipelined-commit n=50",
        &[("mode", "pipelined-commit".to_string()), ("n", n.to_string())],
        &commit_stats,
    );

    // cache hit path: 1024 recorded entries, 64 lookups per iteration
    let store = amt::store::MetadataStore::new();
    let mut rng = Rng::new(7);
    let keys: Vec<String> = (0..1024)
        .map(|_| {
            let key = amt::coordinator::eval_cache_key("branin", &sp.sample(&mut rng));
            store.eval_cache_put(
                &key,
                amt::json::Json::obj(vec![
                    ("owner", amt::json::Json::Str("bench".into())),
                    ("objective", amt::json::Json::Str("branin".into())),
                    (
                        "curve",
                        amt::json::Json::Arr(
                            (0..8).map(|e| amt::json::Json::Num(e as f64)).collect(),
                        ),
                    ),
                    ("final_value", amt::json::Json::Num(0.25)),
                    ("status", amt::json::Json::Str("Completed".into())),
                    ("stopped_early", amt::json::Json::Bool(false)),
                ]),
            );
            key
        })
        .collect();
    let hit_stats = bench("cache hit x64 (1024 entries)", 10, 2000, || {
        for k in &keys[..64] {
            std::hint::black_box(store.eval_cache_get(k));
        }
    });
    report.push(
        "cache hit x64 (1024 entries)",
        &[("mode", "cache-hit".to_string()), ("entries", "1024".to_string())],
        &hit_stats,
    );

    print_table(
        "critical-path latency per landed outcome (p50)",
        &["path", "p50"],
        &[
            vec!["sync propose".to_string(), amt::harness::fmt_secs(sync_stats.p50)],
            vec![
                "pipelined commit".to_string(),
                amt::harness::fmt_secs(commit_stats.p50),
            ],
            vec![
                "cache hit (64 lookups)".to_string(),
                amt::harness::fmt_secs(hit_stats.p50),
            ],
        ],
    );
    match report.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("WARN: could not write bench report: {e}"),
    }
}
