//! Ablation benches for the design choices DESIGN.md §6 calls out.
//! These measure *optimizer quality* (best value reached under a fixed
//! budget, averaged over seeds), not wall time:
//!
//!   1. GPHP treatment: slice-sampling MCMC vs empirical Bayes (§4.2)
//!   2. Input warping on vs off (§4.2) on a non-stationary objective
//!   3. Log scaling on vs off (§5.1) with BO on the XGBoost surrogate
//!   4. Sobol anchor count in the acquisition optimizer (§4.3)
//!   5. Async pending-exclusion on vs off at parallelism 4 (§4.4)
//!   6. Median-rule activation: dynamic vs always-on vs 10-completed (§5.2)
//!
//! `cargo bench --bench ablations [seeds]`

use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use amt::acquisition::AcquisitionConfig;
use amt::config::TuningJobRequest;
use amt::coordinator::TuningJobRunner;
use amt::earlystop::{MedianRule, NoStopping, StoppingPolicy};
use amt::gp::slice::SliceConfig;
use amt::gp::NativeBackend;
use amt::harness::{mean_std, print_table};
use amt::metrics::MetricsService;
use amt::objectives::by_name;
use amt::platform::{PlatformConfig, TrainingPlatform};
use amt::rng::Rng;
use amt::store::MetadataStore;
use amt::strategies::{BayesianOptimization, BoConfig, GphpMode, Observation, Strategy};

/// Run BO directly against an objective's final values (no platform) and
/// return best-so-far after `budget` evaluations.
fn run_bo(objective: &str, config: BoConfig, seed: u64, budget: usize) -> f64 {
    let obj = by_name(objective).unwrap();
    let sign = if obj.minimize() { 1.0 } else { -1.0 };
    let space = obj.space();
    let mut bo = BayesianOptimization::new(space, Arc::new(NativeBackend), config, seed);
    let mut history: Vec<Observation> = Vec::new();
    for i in 0..budget {
        let c = bo.next_config(&history, &[]);
        let v = sign * obj.final_value(&c, seed ^ (i as u64) << 17);
        history.push(Observation { config: c, value: v });
    }
    history.iter().map(|o| o.value).fold(f64::INFINITY, f64::min)
}

fn summarize(name: &str, vals: &[f64]) -> Vec<String> {
    let (m, s) = mean_std(vals);
    vec![name.into(), format!("{m:.4} ± {s:.4}")]
}

fn main() {
    let seeds: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(8);
    let budget = 25;
    let base = || BoConfig {
        init_random: 4,
        gphp: GphpMode::Mcmc(SliceConfig::light()),
        acq: AcquisitionConfig { num_anchors: 256, ..Default::default() },
        ..Default::default()
    };

    // 1. MCMC vs EB on hartmann6 (few-observation regime is where it matters)
    let mut mcmc = Vec::new();
    let mut eb = Vec::new();
    for s in 0..seeds {
        mcmc.push(run_bo("hartmann6", base(), s, budget));
        let mut c = base();
        c.gphp = GphpMode::EmpiricalBayes { restarts: 2 };
        eb.push(run_bo("hartmann6", c, s, budget));
    }
    print_table(
        "ablation 1 — GPHP treatment (hartmann6, lower better)",
        &["variant", "best after 25 evals"],
        &[summarize("slice MCMC (AMT)", &mcmc), summarize("empirical Bayes", &eb)],
    );

    // 2. input warping on/off on the log-sensitive xgboost surface,
    //    *without* log scaling, so warping has to discover the geometry
    let mut warp_on = Vec::new();
    let mut warp_off = Vec::new();
    for s in 0..seeds {
        warp_on.push(run_bo("xgboost_dm_linear", base(), s, budget));
        let mut c = base();
        c.input_warping = false;
        warp_off.push(run_bo("xgboost_dm_linear", c, s, budget));
    }
    print_table(
        "ablation 2 — input warping (xgboost, linear scaling)",
        &["variant", "best after 25 evals"],
        &[summarize("warping on (AMT)", &warp_on), summarize("warping off", &warp_off)],
    );

    // 3. log scaling on/off (same objective, two space definitions)
    let mut log_on = Vec::new();
    let mut log_off = Vec::new();
    for s in 0..seeds {
        log_on.push(run_bo("xgboost_dm", base(), s, budget));
        log_off.push(run_bo("xgboost_dm_linear", base(), s, budget));
    }
    print_table(
        "ablation 3 — log scaling (xgboost direct marketing)",
        &["variant", "best after 25 evals"],
        &[summarize("log scaling (AMT)", &log_on), summarize("linear scaling", &log_off)],
    );

    // 4. anchor count
    let mut rows = Vec::new();
    for anchors in [32usize, 128, 512] {
        let mut vals = Vec::new();
        for s in 0..seeds {
            let mut c = base();
            c.acq.num_anchors = anchors;
            vals.push(run_bo("branin", c, s, budget));
        }
        rows.push(summarize(&format!("{anchors} anchors"), &vals));
    }
    print_table("ablation 4 — Sobol anchor count (branin)", &["variant", "best"], &rows);

    // 5. pending exclusion at parallelism 4 (platform-driven, async)
    let run_parallel = |exclusion: f64, seed: u64| -> f64 {
        let obj: Arc<dyn amt::objectives::Objective> = by_name("branin").unwrap().into();
        let mut c = base();
        c.acq.exclusion_radius = exclusion;
        let strat: Box<dyn Strategy> =
            Box::new(BayesianOptimization::new(obj.space(), Arc::new(NativeBackend), c, seed));
        let request = TuningJobRequest {
            name: format!("abl5-{exclusion}-{seed}"),
            objective: "branin".into(),
            strategy: "bayesian".into(),
            max_training_jobs: budget as u32,
            max_parallel_jobs: 4,
            seed,
            ..Default::default()
        };
        let out = TuningJobRunner::new(
            request,
            obj,
            strat,
            Box::new(NoStopping),
            TrainingPlatform::new(PlatformConfig::noiseless(), seed),
            Arc::new(MetadataStore::new()),
            Arc::new(MetricsService::new()),
            Arc::new(AtomicBool::new(false)),
        )
        .run();
        out.best.map(|b| b.1).unwrap_or(f64::INFINITY)
    };
    let mut with_ex = Vec::new();
    let mut without_ex = Vec::new();
    for s in 0..seeds {
        with_ex.push(run_parallel(0.08, s));
        without_ex.push(run_parallel(1e-9, s)); // radius→0 disables the penalty
    }
    print_table(
        "ablation 5 — async pending exclusion (branin, L=4)",
        &["variant", "best after 25 evals"],
        &[
            summarize("exclusion on (AMT)", &with_ex),
            summarize("exclusion off", &without_ex),
        ],
    );

    // 6. median-rule activation policies: time saved vs quality lost
    let run_es = |policy: Box<dyn StoppingPolicy>, seed: u64| -> (f64, f64) {
        let obj: Arc<dyn amt::objectives::Objective> =
            by_name("gdelt_single").unwrap().into();
        let strat = amt::strategies::by_name(
            "random",
            &obj.space(),
            Arc::new(NativeBackend),
            seed,
        )
        .unwrap();
        let request = TuningJobRequest {
            name: format!("abl6-{seed}-{}", policy.name()),
            objective: "gdelt_single".into(),
            strategy: "random".into(),
            max_training_jobs: 40,
            max_parallel_jobs: 2,
            seed,
            ..Default::default()
        };
        let out = TuningJobRunner::new(
            request,
            obj,
            strat,
            policy,
            TrainingPlatform::new(PlatformConfig::noiseless(), seed),
            Arc::new(MetadataStore::new()),
            Arc::new(MetricsService::new()),
            Arc::new(AtomicBool::new(false)),
        )
        .run();
        (out.best.map(|b| b.1).unwrap_or(f64::INFINITY), out.total_seconds)
    };
    let mut rows = Vec::new();
    type PolicyMaker = fn() -> Box<dyn StoppingPolicy>;
    let variants: [(&str, PolicyMaker); 4] = [
        ("off", || Box::new(NoStopping)),
        ("dynamic activation (AMT)", || Box::new(MedianRule::default())),
        ("always-on (fraction 0)", || {
            Box::new(MedianRule { activation_fraction: 0.0, min_epochs: 1, ..Default::default() })
        }),
        ("10-completed safeguard", || {
            Box::new(MedianRule { min_completed_jobs: 10, ..Default::default() })
        }),
    ];
    for (name, make) in variants {
        let mut loss = Vec::new();
        let mut time = Vec::new();
        for s in 0..seeds {
            let (l, t) = run_es(make(), s);
            loss.push(l);
            time.push(t / 3600.0);
        }
        let (lm, _) = mean_std(&loss);
        let (tm, _) = mean_std(&time);
        rows.push(vec![name.to_string(), format!("{lm:.4}"), format!("{tm:.2}h")]);
    }
    print_table(
        "ablation 6 — median-rule activation (gdelt, 40 evals)",
        &["variant", "final loss", "wall time"],
        &rows,
    );

    let _ = Rng::new(0);
}
