//! L1 kernel bench: Gram-matrix construction across train-set buckets,
//! native Rust vs the AOT HLO artifact (Pallas kernel via PJRT).
//!
//! This is the innermost hot spot of GP fitting: one Gram per
//! slice-sampling likelihood query (~600 per BO proposal under the paper's
//! MCMC settings). Run with `cargo bench --bench kernel_matrix`.

use amt::gp::{Dataset, NativeBackend, SurrogateBackend, Theta};
use amt::harness::{bench, print_table};
use amt::rng::Rng;
use amt::runtime::{HloBackend, HloRuntime};

fn points(n: usize, d: usize, rng: &mut Rng) -> Dataset {
    Dataset::from_fn(n, d, |_, _| rng.uniform())
}

fn main() {
    let mut rng = Rng::new(1);
    let d = 8;
    let theta = Theta::default_for_dim(d);
    let hlo = HloRuntime::open_default().ok().map(HloBackend::artifacts_only);
    if hlo.is_none() {
        eprintln!("NOTE: artifacts missing; HLO column skipped (`make artifacts`)");
    }

    let mut rows = Vec::new();
    for n in [16usize, 32, 64, 128, 256, 512] {
        let x = points(n, d, &mut rng);
        let iters = (20_000 / n).max(5);
        let nat = bench(&format!("gram native   n={n}"), 2, iters, || {
            let k = NativeBackend.gram(&x, &theta);
            std::hint::black_box(k);
        });
        let hlo_stats = hlo.as_ref().map(|b| {
            bench(&format!("gram hlo/pjrt n={n}"), 2, iters.min(100), || {
                let k = b.gram(&x, &theta);
                std::hint::black_box(k);
            })
        });
        rows.push(vec![
            n.to_string(),
            amt::harness::fmt_secs(nat.p50),
            hlo_stats
                .map(|s| amt::harness::fmt_secs(s.p50))
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    print_table("Gram matrix p50 latency", &["n", "native", "hlo/pjrt"], &rows);
}
