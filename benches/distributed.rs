//! Distributed-plane benchmark (DESIGN.md §11): frame encode/decode
//! throughput, loopback leader⇄worker round-trip latency, and a 200-job
//! soak through the loopback `RemoteWorkerPool`. Emits
//! `BENCH_distributed.json` (schema in `harness::BenchReport`;
//! `AMT_BENCH_DIR` overrides the output directory).
//! `cargo bench --bench distributed`.

use std::time::{Duration, Instant};

use amt::config::TuningJobRequest;
use amt::distributed::proto::{Message, PollReply};
use amt::distributed::worker::spawn_loopback_worker;
use amt::distributed::{frame, transport::Transport};
use amt::durability::wal::WalRecord;
use amt::harness::{bench, BenchReport, BenchStats};
use amt::json::Json;
use amt::platform::PlatformConfig;

/// A representative `StoreDelta`: one poll slice's worth of records.
fn sample_delta() -> Message {
    let mut records = Vec::new();
    for i in 0..16u64 {
        records.push((
            i + 1,
            WalRecord::Put {
                table: "training_jobs".into(),
                key: format!("soak-0001-train-{i:04}"),
                version: 1,
                value: Json::obj(vec![
                    ("tuning_job", Json::Str("soak-0001".into())),
                    ("status", Json::Str("Completed".into())),
                    ("final_value", Json::Num(0.123456789 * i as f64)),
                    ("attempts", Json::Num(1.0)),
                ]),
            },
        ));
        records.push((
            i + 100,
            WalRecord::Emit {
                stream: format!("soak-0001-train-{i:04}/objective"),
                time: 30.0 * i as f64,
                value: 1.0 / (1.0 + i as f64),
            },
        ));
    }
    Message::StoreDelta { job: "soak-0001".into(), records }
}

fn main() {
    let mut report = BenchReport::new("distributed");
    const FRAMES: usize = 2_000;

    // --- frame + message encode throughput (the worker's per-slice
    // serialization cost) ---
    let msg = sample_delta();
    let encoded = msg.encode();
    let frame_bytes = encoded.len();
    let stats = bench("delta encode 2k frames (32 recs each)", 1, 5, || {
        for _ in 0..FRAMES {
            std::hint::black_box(msg.encode());
        }
    });
    report.push(
        "frame_encode",
        &[
            ("frames", FRAMES.to_string()),
            ("frame_bytes", frame_bytes.to_string()),
            (
                "mb_per_sec",
                format!("{:.1}", FRAMES as f64 * frame_bytes as f64 / stats.p50 / 1e6),
            ),
        ],
        &stats,
    );

    // --- decode throughput (the leader's per-slice parse cost) ---
    let stats = bench("delta decode 2k frames", 1, 5, || {
        for _ in 0..FRAMES {
            let (payload, _) = frame::decode(&encoded).unwrap().unwrap();
            std::hint::black_box(Message::decode(&payload).unwrap());
        }
    });
    report.push(
        "frame_decode",
        &[
            ("frames", FRAMES.to_string()),
            ("frame_bytes", frame_bytes.to_string()),
            (
                "mb_per_sec",
                format!("{:.1}", FRAMES as f64 * frame_bytes as f64 / stats.p50 / 1e6),
            ),
        ],
        &stats,
    );

    // --- loopback round-trip latency: PollRequest for an unknown job →
    // Rejected (pure protocol overhead, no tuning work) ---
    let (mut leader, _fault, handle) = spawn_loopback_worker("bench-rtt");
    const ROUNDTRIPS: usize = 1_000;
    let stats = bench("loopback round-trip x1000", 1, 5, || {
        for _ in 0..ROUNDTRIPS {
            leader
                .send(&Message::PollRequest { job: "nope".into(), max_steps: 1 })
                .unwrap();
            loop {
                match leader.recv(Duration::from_secs(10)).unwrap() {
                    Some(Message::PollResult {
                        reply: PollReply::Rejected { .. }, ..
                    }) => break,
                    Some(_) => {} // Hello / heartbeats
                    None => panic!("worker went quiet"),
                }
            }
        }
    });
    report.push(
        "loopback_rtt",
        &[
            ("roundtrips", ROUNDTRIPS.to_string()),
            ("rtt_us_p50", format!("{:.1}", stats.p50 / ROUNDTRIPS as f64 * 1e6)),
        ],
        &stats,
    );
    leader.send(&Message::Drain).unwrap();
    handle.join().unwrap();

    // --- 200-job soak through the loopback RemoteWorkerPool ---
    const SOAK_JOBS: usize = 200;
    const WORKERS: usize = 4;
    let mut transports: Vec<Box<dyn Transport>> = Vec::new();
    let mut handles = Vec::new();
    for i in 0..WORKERS {
        let (t, _fault, h) = spawn_loopback_worker(&format!("bench-soak-{i}"));
        transports.push(t);
        handles.push(h);
    }
    let service =
        amt::api::AmtService::with_remote_workers(PlatformConfig::default(), transports);
    let started = Instant::now();
    let mut latencies = Vec::with_capacity(SOAK_JOBS);
    for i in 0..SOAK_JOBS {
        let t = Instant::now();
        service
            .create_tuning_job(TuningJobRequest {
                name: format!("dsoak-{i:04}"),
                objective: "branin".into(),
                strategy: "random".into(),
                max_training_jobs: 5,
                max_parallel_jobs: 5,
                seed: i as u64,
                ..Default::default()
            })
            .unwrap();
        latencies.push(t.elapsed().as_secs_f64());
    }
    let mut evaluations = 0usize;
    for i in 0..SOAK_JOBS {
        let outcome = service.wait(&format!("dsoak-{i:04}")).unwrap();
        evaluations += outcome.evaluations.len();
    }
    let wall = started.elapsed().as_secs_f64();
    let stats = BenchStats::from_samples(latencies);
    println!(
        "distributed soak: {SOAK_JOBS} jobs / {evaluations} evaluations over {WORKERS} \
         loopback workers in {wall:.1}s ({:.1} jobs/s)",
        SOAK_JOBS as f64 / wall
    );
    report.push(
        "remote_soak_200",
        &[
            ("jobs", SOAK_JOBS.to_string()),
            ("workers", WORKERS.to_string()),
            ("evaluations", evaluations.to_string()),
            ("jobs_per_sec", format!("{:.2}", SOAK_JOBS as f64 / wall)),
            ("wall_s", format!("{wall:.3}")),
        ],
        &stats,
    );
    drop(service);
    for h in handles {
        h.join().unwrap();
    }

    match report.write() {
        Ok(path) => eprintln!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write BENCH_distributed.json: {e}"),
    }
}
