//! Distributed-plane benchmark (DESIGN.md §11, §13): frame
//! encode/decode throughput, loopback leader⇄worker round-trip latency,
//! a 200-job soak through the loopback `RemoteWorkerPool` (now also
//! reporting messages-per-slice and store writes-per-lock, DESIGN.md
//! §14, plus real per-op p50/p99/p999 latency histograms from the
//! telemetry plane, §15), an elastic kill/join/drain scenario reporting
//! fleet-size-vs-throughput, a graceful-drain migration-latency
//! microbench (p50/p99), a batched-vs-per-record delta-application
//! comparison, and a cross-driver group-commit fan-in scenario. Emits
//! `BENCH_distributed.json` (schema in `harness::BenchReport`;
//! `AMT_BENCH_DIR` overrides the output directory).
//! `cargo bench --bench distributed`.

use std::time::{Duration, Instant};

use amt::config::TuningJobRequest;
use amt::distributed::leader::RemoteConfig;
use amt::distributed::proto::{Message, PollReply};
use amt::distributed::worker::spawn_loopback_worker;
use amt::distributed::{frame, transport::Transport};
use amt::durability::wal::WalRecord;
use amt::harness::{bench, BenchReport, BenchStats};
use amt::json::Json;
use amt::platform::PlatformConfig;

/// A representative `StoreDelta`: one poll slice's worth of records.
fn sample_delta() -> Message {
    let mut records = Vec::new();
    for i in 0..16u64 {
        records.push((
            i + 1,
            WalRecord::Put {
                table: "training_jobs".into(),
                key: format!("soak-0001-train-{i:04}"),
                version: 1,
                value: Json::obj(vec![
                    ("tuning_job", Json::Str("soak-0001".into())),
                    ("status", Json::Str("Completed".into())),
                    ("final_value", Json::Num(0.123456789 * i as f64)),
                    ("attempts", Json::Num(1.0)),
                ]),
            },
        ));
        records.push((
            i + 100,
            WalRecord::Emit {
                stream: format!("soak-0001-train-{i:04}/objective"),
                time: 30.0 * i as f64,
                value: 1.0 / (1.0 + i as f64),
            },
        ));
    }
    Message::StoreDelta { job: "soak-0001".into(), records }
}

fn main() {
    let mut report = BenchReport::new("distributed");
    const FRAMES: usize = 2_000;

    // --- frame + message encode throughput (the worker's per-slice
    // serialization cost) ---
    let msg = sample_delta();
    let encoded = msg.encode();
    let frame_bytes = encoded.len();
    let stats = bench("delta encode 2k frames (32 recs each)", 1, 5, || {
        for _ in 0..FRAMES {
            std::hint::black_box(msg.encode());
        }
    });
    report.push(
        "frame_encode",
        &[
            ("frames", FRAMES.to_string()),
            ("frame_bytes", frame_bytes.to_string()),
            (
                "mb_per_sec",
                format!("{:.1}", FRAMES as f64 * frame_bytes as f64 / stats.p50 / 1e6),
            ),
        ],
        &stats,
    );

    // --- decode throughput (the leader's per-slice parse cost) ---
    let stats = bench("delta decode 2k frames", 1, 5, || {
        for _ in 0..FRAMES {
            let (payload, _) = frame::decode(&encoded).unwrap().unwrap();
            std::hint::black_box(Message::decode(&payload).unwrap());
        }
    });
    report.push(
        "frame_decode",
        &[
            ("frames", FRAMES.to_string()),
            ("frame_bytes", frame_bytes.to_string()),
            (
                "mb_per_sec",
                format!("{:.1}", FRAMES as f64 * frame_bytes as f64 / stats.p50 / 1e6),
            ),
        ],
        &stats,
    );

    // --- loopback round-trip latency: PollRequest for an unknown job →
    // Rejected (pure protocol overhead, no tuning work) ---
    let (mut leader, _fault, handle) = spawn_loopback_worker("bench-rtt");
    const ROUNDTRIPS: usize = 1_000;
    let stats = bench("loopback round-trip x1000", 1, 5, || {
        for _ in 0..ROUNDTRIPS {
            leader
                .send(&Message::PollRequest { job: "nope".into(), max_steps: 1 })
                .unwrap();
            loop {
                match leader.recv(Duration::from_secs(10)).unwrap() {
                    Some(Message::PollResult {
                        reply: PollReply::Rejected { .. }, ..
                    }) => break,
                    Some(_) => {} // Hello / heartbeats
                    None => panic!("worker went quiet"),
                }
            }
        }
    });
    report.push(
        "loopback_rtt",
        &[
            ("roundtrips", ROUNDTRIPS.to_string()),
            ("rtt_us_p50", format!("{:.1}", stats.p50 / ROUNDTRIPS as f64 * 1e6)),
        ],
        &stats,
    );
    leader.send(&Message::Drain).unwrap();
    handle.join().unwrap();

    // --- 200-job soak through the loopback RemoteWorkerPool ---
    const SOAK_JOBS: usize = 200;
    const WORKERS: usize = 4;
    let mut transports: Vec<Box<dyn Transport>> = Vec::new();
    let mut handles = Vec::new();
    for i in 0..WORKERS {
        let (t, _fault, h) = spawn_loopback_worker(&format!("bench-soak-{i}"));
        transports.push(t);
        handles.push(h);
    }
    let service =
        amt::api::AmtService::with_remote_workers(PlatformConfig::default(), transports);
    let started = Instant::now();
    let mut latencies = Vec::with_capacity(SOAK_JOBS);
    for i in 0..SOAK_JOBS {
        let t = Instant::now();
        service
            .create_tuning_job(TuningJobRequest {
                name: format!("dsoak-{i:04}"),
                objective: "branin".into(),
                strategy: "random".into(),
                max_training_jobs: 5,
                max_parallel_jobs: 5,
                seed: i as u64,
                ..Default::default()
            })
            .unwrap();
        latencies.push(t.elapsed().as_secs_f64());
    }
    let mut evaluations = 0usize;
    for i in 0..SOAK_JOBS {
        let outcome = service.wait(&format!("dsoak-{i:04}")).unwrap();
        evaluations += outcome.evaluations.len();
    }
    let wall = started.elapsed().as_secs_f64();
    let stats = BenchStats::from_samples(latencies);
    // throughput-plane counters (DESIGN.md §14): the coalesced wire
    // averages ~1 worker→leader frame per slice (legacy pair: 2), and
    // the batched apply amortizes shard locks over whole slices
    // (per-record baseline: ≥1 lock per write)
    let pool = service.remote_pool().unwrap();
    let polls = pool.polls_dispatched().max(1);
    let slice_msgs = pool.slice_messages();
    let store_locks = service.store().shard_lock_acquisitions().max(1);
    let store_writes = service.store().write_count();
    println!(
        "distributed soak: {SOAK_JOBS} jobs / {evaluations} evaluations over {WORKERS} \
         loopback workers in {wall:.1}s ({:.1} jobs/s); {:.2} msgs/slice, {:.2} writes/lock",
        SOAK_JOBS as f64 / wall,
        slice_msgs as f64 / polls as f64,
        store_writes as f64 / store_locks as f64
    );
    report.push(
        "remote_soak_200",
        &[
            ("jobs", SOAK_JOBS.to_string()),
            ("workers", WORKERS.to_string()),
            ("evaluations", evaluations.to_string()),
            ("jobs_per_sec", format!("{:.2}", SOAK_JOBS as f64 / wall)),
            ("wall_s", format!("{wall:.3}")),
            ("slice_messages", slice_msgs.to_string()),
            ("polls", polls.to_string()),
            ("msgs_per_slice", format!("{:.2}", slice_msgs as f64 / polls as f64)),
            ("store_shard_locks", store_locks.to_string()),
            ("store_writes", store_writes.to_string()),
            ("writes_per_lock", format!("{:.2}", store_writes as f64 / store_locks as f64)),
        ],
        &stats,
    );
    // per-op latency histograms from the telemetry plane (DESIGN.md §15):
    // real p50/p99/p999 for the soak's wire round-trips and store batches
    let snap = service.telemetry_snapshot();
    for metric in ["leader.rtt_us", "store.put_batch_us", "scheduler.poll_slice_us"] {
        if let Some(h) = snap.histogram(metric) {
            if h.count > 0 {
                println!(
                    "  {metric}: n={} p50={}µs p99={}µs p999={}µs",
                    h.count, h.p50, h.p99, h.p999
                );
                report.push_histogram(
                    &format!("remote_soak_200 {metric}"),
                    &[("jobs", SOAK_JOBS.to_string()), ("metric", metric.to_string())],
                    h,
                );
            }
        }
    }
    drop(pool);
    drop(service);
    for h in handles {
        h.join().unwrap();
    }

    // --- elastic fleet under load (DESIGN.md §13): per-phase throughput
    // as the fleet shrinks to a kill, grows at a late join, and shrinks
    // again at a graceful drain ---
    const ELASTIC_JOBS: usize = 240;
    let mut transports: Vec<Box<dyn Transport>> = Vec::new();
    let mut handles = Vec::new();
    let mut faults = Vec::new();
    for i in 0..3 {
        let (t, fault, h) = spawn_loopback_worker(&format!("bench-elastic-{i}"));
        transports.push(t);
        faults.push(fault);
        handles.push(h);
    }
    let mut service = amt::api::AmtService::new(PlatformConfig::default());
    service.attach_remote_workers(
        transports,
        RemoteConfig { batch_steps: 16, ..RemoteConfig::default() },
    );
    let names: Vec<String> = (0..ELASTIC_JOBS).map(|i| format!("elast-{i:04}")).collect();
    for (i, name) in names.iter().enumerate() {
        service
            .create_tuning_job(TuningJobRequest {
                name: name.clone(),
                objective: "branin".into(),
                strategy: "random".into(),
                max_training_jobs: 3,
                max_parallel_jobs: 2,
                seed: i as u64,
                ..Default::default()
            })
            .unwrap();
    }
    let pool = service.remote_pool().unwrap();
    let await_done = |target: usize| {
        let deadline = Instant::now() + Duration::from_secs(300);
        loop {
            let done = names.iter().filter(|n| pool.try_outcome(n).is_some()).count();
            if done >= target {
                return Instant::now();
            }
            assert!(Instant::now() < deadline, "elastic fleet stalled at {done}/{target}");
            std::thread::yield_now();
        }
    };
    let quarter = ELASTIC_JOBS / 4;
    let t0 = Instant::now();
    let t1 = await_done(quarter); // 3 workers
    faults[0].kill();
    let t2 = await_done(2 * quarter); // 2 workers (post-kill repair)
    let (late_t, _late_fault, late_h) = spawn_loopback_worker("bench-elastic-late");
    handles.push(late_h);
    service.add_remote_worker(late_t).unwrap();
    let t3 = await_done(3 * quarter); // 3 workers again (join + steal)
    assert!(service.drain_remote_worker(1));
    for name in &names {
        service.wait(name).unwrap();
    }
    let t4 = Instant::now(); // 2 workers (post-drain)
    let phase = |a: Instant, b: Instant| quarter as f64 / (b - a).as_secs_f64();
    println!(
        "elastic fleet: {:.1} jobs/s @3w → {:.1} @2w (kill) → {:.1} @3w (join) → {:.1} @2w (drain); \
         steals={} requeues={}/{} replays={}",
        phase(t0, t1),
        phase(t1, t2),
        phase(t2, t3),
        phase(t3, t4),
        pool.steals(),
        pool.snapshot_requeues(),
        pool.scratch_requeues(),
        pool.replayed_proposals()
    );
    report.push(
        "elastic_kill_join_drain_240",
        &[
            ("jobs", ELASTIC_JOBS.to_string()),
            ("jobs_per_sec_3w", format!("{:.2}", phase(t0, t1))),
            ("jobs_per_sec_2w_postkill", format!("{:.2}", phase(t1, t2))),
            ("jobs_per_sec_3w_postjoin", format!("{:.2}", phase(t2, t3))),
            ("jobs_per_sec_2w_postdrain", format!("{:.2}", phase(t3, t4))),
            ("joins", pool.joins().to_string()),
            ("drains", pool.drains().to_string()),
            ("steals", pool.steals().to_string()),
            ("replayed_proposals", pool.replayed_proposals().to_string()),
        ],
        &BenchStats::from_samples(vec![
            (t1 - t0).as_secs_f64(),
            (t2 - t1).as_secs_f64(),
            (t3 - t2).as_secs_f64(),
            (t4 - t3).as_secs_f64(),
        ]),
    );
    drop(pool);
    drop(service);
    for h in handles {
        let _ = h.join();
    }

    // --- graceful-drain migration latency under load: time from
    // drain_worker() to the lane fully migrated + retired, repeated over
    // a rolling fleet (always one join ahead, so two lanes stay live) ---
    const MIG_CYCLES: usize = 12;
    let mut transports: Vec<Box<dyn Transport>> = Vec::new();
    let mut handles = Vec::new();
    for i in 0..2 {
        let (t, _fault, h) = spawn_loopback_worker(&format!("bench-mig-{i}"));
        transports.push(t);
        handles.push(h);
    }
    let mut service = amt::api::AmtService::new(PlatformConfig::default());
    service.attach_remote_workers(
        transports,
        RemoteConfig { batch_steps: 4, ..RemoteConfig::default() },
    );
    // long-running jobs keep every drained lane loaded with work to move
    for i in 0..8 {
        service
            .create_tuning_job(TuningJobRequest {
                name: format!("mig-{i}"),
                objective: "branin".into(),
                strategy: "random".into(),
                max_training_jobs: 500,
                max_parallel_jobs: 2,
                seed: i as u64,
                ..Default::default()
            })
            .unwrap();
    }
    let pool = service.remote_pool().unwrap();
    let mut mig_latencies = Vec::with_capacity(MIG_CYCLES);
    for cycle in 0..MIG_CYCLES {
        let (t, _fault, h) = spawn_loopback_worker(&format!("bench-mig-join-{cycle}"));
        service.add_remote_worker(t).unwrap();
        handles.push(h);
        let t0 = Instant::now();
        assert!(service.drain_remote_worker(cycle), "lane {cycle} should drain");
        let deadline = Instant::now() + Duration::from_secs(60);
        while pool.drains() < cycle as u64 + 1 {
            assert!(Instant::now() < deadline, "drain {cycle} never completed");
            std::thread::yield_now();
        }
        mig_latencies.push(t0.elapsed().as_secs_f64());
    }
    for i in 0..8 {
        let _ = service.stop_tuning_job(&format!("mig-{i}"));
    }
    for i in 0..8 {
        service.wait(&format!("mig-{i}")).unwrap();
    }
    let mut sorted = mig_latencies.clone();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let p99 = sorted[((sorted.len() - 1) as f64 * 0.99) as usize];
    let stats = BenchStats::from_samples(mig_latencies);
    println!(
        "drain migration latency over {MIG_CYCLES} cycles: p50 {:.1}ms, p99 {:.1}ms \
         (replays: {})",
        stats.p50 * 1e3,
        p99 * 1e3,
        pool.replayed_proposals()
    );
    report.push(
        "drain_migration_latency",
        &[
            ("cycles", MIG_CYCLES.to_string()),
            ("migration_p50_ms", format!("{:.3}", stats.p50 * 1e3)),
            ("migration_p99_ms", format!("{:.3}", p99 * 1e3)),
            ("replayed_proposals", pool.replayed_proposals().to_string()),
        ],
        &stats,
    );
    drop(pool);
    drop(service);
    for h in handles {
        let _ = h.join();
    }

    // --- batched vs per-record delta application (DESIGN.md §14): the
    // leader's apply cost for a slice of 16 puts + 16 emits, WAL
    // attached (fsync off: measure locks + appends, not the disk) ---
    use amt::durability::wal::Wal;
    use amt::metrics::MetricsService;
    use amt::store::{MetadataStore, StoreBatchOp};
    use std::sync::Arc;
    const APPLY_SLICES: usize = 400;
    let bench_dir = std::env::temp_dir().join(format!(
        "amt-bench-throughput-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&bench_dir);
    let attach = |name: &str| {
        let wal = Arc::new(Wal::create(&bench_dir.join(name)).unwrap());
        wal.set_fsync(false);
        let store = MetadataStore::new();
        let metrics = MetricsService::new();
        store.attach_wal(Arc::clone(&wal));
        metrics.attach_wal(Arc::clone(&wal));
        (store, metrics, wal)
    };
    let slice_puts: Vec<(String, Json)> = (0..16usize)
        .map(|i| (format!("apply-train-{i:04}"), Json::Num(i as f64)))
        .collect();
    let slice_emits: Vec<(String, f64, f64)> = (0..16usize)
        .map(|i| (format!("apply-{i:02}/objective"), i as f64, 0.5))
        .collect();

    let (store, metrics, wal) = attach("per-record");
    let stats_per = bench("delta apply per-record (400 slices x 32 recs)", 1, 5, || {
        for _ in 0..APPLY_SLICES {
            for (key, value) in &slice_puts {
                store.put("training_jobs", key, value.clone());
            }
            for (stream, time, value) in &slice_emits {
                metrics.emit(stream, *time, *value);
            }
            wal.commit().unwrap();
        }
    });
    let locks_per = store.shard_lock_acquisitions() + metrics.shard_lock_acquisitions();
    report.push(
        "delta_apply_per_record",
        &[
            ("slices", APPLY_SLICES.to_string()),
            ("records_per_slice", "32".into()),
            ("shard_locks", locks_per.to_string()),
        ],
        &stats_per,
    );

    let (store, metrics, wal) = attach("batched");
    let stats_bat = bench("delta apply batched (400 slices x 32 recs)", 1, 5, || {
        for _ in 0..APPLY_SLICES {
            let ops: Vec<StoreBatchOp<'_>> = slice_puts
                .iter()
                .map(|(key, value)| StoreBatchOp::Put {
                    table: "training_jobs",
                    key,
                    value,
                })
                .collect();
            store.put_batch(&ops);
            let points: Vec<(&str, f64, f64)> = slice_emits
                .iter()
                .map(|(stream, time, value)| (stream.as_str(), *time, *value))
                .collect();
            metrics.emit_batch(&points);
            wal.commit().unwrap();
        }
    });
    let locks_bat = store.shard_lock_acquisitions() + metrics.shard_lock_acquisitions();
    println!(
        "delta apply: per-record p50 {:.1}ms / {} locks, batched p50 {:.1}ms / {} locks \
         ({:.1}x lock reduction)",
        stats_per.p50 * 1e3,
        locks_per,
        stats_bat.p50 * 1e3,
        locks_bat,
        locks_per as f64 / locks_bat.max(1) as f64
    );
    report.push(
        "delta_apply_batched",
        &[
            ("slices", APPLY_SLICES.to_string()),
            ("records_per_slice", "32".into()),
            ("shard_locks", locks_bat.to_string()),
            (
                "lock_reduction",
                format!("{:.1}", locks_per as f64 / locks_bat.max(1) as f64),
            ),
            ("speedup_p50", format!("{:.2}", stats_per.p50 / stats_bat.p50)),
        ],
        &stats_bat,
    );

    // --- cross-driver group-commit fan-in: 8 committers hammer one WAL
    // (fsync ON — sharing the fsync is the point) with a 1ms coalescing
    // window; physical fsyncs should land well under the request count ---
    const COMMITTERS: usize = 8;
    const COMMITS_EACH: usize = 40;
    let wal = Arc::new(Wal::create(&bench_dir.join("group-commit")).unwrap());
    wal.set_commit_window(Duration::from_millis(1));
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for t in 0..COMMITTERS {
            let wal = Arc::clone(&wal);
            scope.spawn(move || {
                for c in 0..COMMITS_EACH {
                    wal.append(&WalRecord::Emit {
                        stream: format!("gc-{t}"),
                        time: c as f64,
                        value: 0.0,
                    });
                    wal.commit().unwrap();
                }
            });
        }
    });
    let gc_wall = t0.elapsed().as_secs_f64();
    let fsyncs = wal.commits();
    let coalesced = wal.coalesced();
    let requested = (COMMITTERS * COMMITS_EACH) as u64;
    println!(
        "group commit: {requested} commit requests from {COMMITTERS} threads → {fsyncs} \
         physical write+fsync cycles ({coalesced} coalesced) in {:.2}s",
        gc_wall
    );
    report.push(
        "group_commit_fanin",
        &[
            ("committers", COMMITTERS.to_string()),
            ("commit_requests", requested.to_string()),
            ("physical_commits", fsyncs.to_string()),
            ("coalesced", coalesced.to_string()),
            (
                "fanin",
                format!("{:.2}", requested as f64 / fsyncs.max(1) as f64),
            ),
        ],
        &BenchStats::from_samples(vec![gc_wall]),
    );
    let _ = std::fs::remove_dir_all(&bench_dir);

    match report.write() {
        Ok(path) => eprintln!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write BENCH_distributed.json: {e}"),
    }
}
