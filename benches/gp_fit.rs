//! GPHP-fitting bench: the paper's slice-sampling MCMC spec (§4.2 — 300
//! samples, 250 burn-in, thin 5) vs the light harness preset vs empirical
//! Bayes, across training-set sizes. Run with `cargo bench --bench gp_fit`.
//!
//! Emits `BENCH_gp_fit.json` alongside the printed table (see
//! `scripts/bench.sh`).

use amt::gp::fit::fit_empirical_bayes;
use amt::gp::slice::{sample_gphp, SliceConfig};
use amt::gp::{normalization, Dataset, NativeBackend};
use amt::harness::{bench, print_table, BenchReport};
use amt::rng::Rng;

fn data(n: usize, d: usize, seed: u64) -> (Dataset, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let x = Dataset::from_fn(n, d, |_, _| rng.uniform());
    let y_raw: Vec<f64> =
        x.rows().map(|p| (5.0 * p[0]).sin() + p[1] + 0.05 * rng.normal()).collect();
    let (m, s) = normalization(&y_raw);
    (x, y_raw.iter().map(|v| (v - m) / s).collect())
}

fn main() {
    let d = 4;
    let mut report = BenchReport::new("gp_fit");
    let mut rows = Vec::new();
    for n in [10usize, 25, 50, 100, 200] {
        let (x, y) = data(n, d, n as u64);
        let iters = if n <= 50 { 5 } else { 3 };

        let mut rng = Rng::new(7);
        let paper = bench(&format!("slice paper-spec n={n}"), 1, iters, || {
            let t = sample_gphp(
                &NativeBackend, &x, &y, d, &SliceConfig::default(), &mut rng, None,
            );
            std::hint::black_box(t);
        });
        let mut rng = Rng::new(7);
        let light = bench(&format!("slice light      n={n}"), 1, iters, || {
            let t =
                sample_gphp(&NativeBackend, &x, &y, d, &SliceConfig::light(), &mut rng, None);
            std::hint::black_box(t);
        });
        let mut rng = Rng::new(7);
        let eb = bench(&format!("empirical bayes  n={n}"), 1, iters, || {
            let t = fit_empirical_bayes(&NativeBackend, &x, &y, d, 1, &mut rng);
            std::hint::black_box(t);
        });
        for (variant, stats) in
            [("mcmc-paper", &paper), ("mcmc-light", &light), ("empirical-bayes", &eb)]
        {
            report.push(
                &format!("gp_fit {variant} n={n}"),
                &[
                    ("variant", variant.to_string()),
                    ("n", n.to_string()),
                    ("d", d.to_string()),
                ],
                stats,
            );
        }
        rows.push(vec![
            n.to_string(),
            amt::harness::fmt_secs(paper.p50),
            amt::harness::fmt_secs(light.p50),
            amt::harness::fmt_secs(eb.p50),
        ]);
    }
    print_table(
        "GPHP fit p50 latency (native backend)",
        &["n", "MCMC (paper spec)", "MCMC (light)", "empirical Bayes"],
        &rows,
    );
    match report.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("WARN: could not write bench report: {e}"),
    }
}
